//! Property-based tests over the resource model and simulator invariants
//! (in-repo `testing::check` harness; no external proptest offline).

use scalable_ep::bench::{
    FeatureSet, Features, MsgRateConfig, MsgRateResult, Runner, SharedResource,
};
use scalable_ep::endpoints::{
    BufLayout, Category, CqDepth, EndpointPolicy, MrMap, QpProvision, ResourceUsage, UarMap, Ways,
};
use scalable_ep::mlx5::Mlx5Env;
use scalable_ep::sim::{Server, SimLock, XorShift};
use scalable_ep::testing::check;
use scalable_ep::vci::{pooled_threads, run_pooled, EndpointPool, MapStrategy, Stream, VciMapper};
use scalable_ep::verbs::{Fabric, QpCaps, TdInitAttr};

/// Seed for the randomized differential fuzzers: `SCEP_FUZZ_SEED=<u64>`
/// overrides the fixed default. CI runs the suite twice — once fixed,
/// once randomized with the seed echoed — so every failure log carries
/// its reproduction recipe.
fn fuzz_seed(default: u64) -> u64 {
    match std::env::var("SCEP_FUZZ_SEED") {
        Ok(s) => {
            let seed = s
                .trim()
                .parse::<u64>()
                .unwrap_or_else(|e| panic!("SCEP_FUZZ_SEED={s:?} is not a u64: {e}"));
            eprintln!("[properties] SCEP_FUZZ_SEED={seed} (reproduce with this env var)");
            seed
        }
        Err(_) => default,
    }
}

/// Assert every virtual-time observable of a fast-path run equals the
/// stepped general path's, bit for bit; scheduler diagnostics must show
/// identical trajectories (same step count) and no extra dispatches.
fn assert_bit_exact(
    fast: &MsgRateResult,
    general: &MsgRateResult,
    what: &str,
) -> Result<(), String> {
    if fast.duration != general.duration {
        return Err(format!("{what}: duration {} vs {}", fast.duration, general.duration));
    }
    if fast.thread_done != general.thread_done {
        return Err(format!("{what}: per-thread done-times diverged"));
    }
    if fast.messages != general.messages {
        return Err(format!("{what}: messages {} vs {}", fast.messages, general.messages));
    }
    if fast.mmsgs_per_sec != general.mmsgs_per_sec {
        return Err(format!("{what}: rate {} vs {}", fast.mmsgs_per_sec, general.mmsgs_per_sec));
    }
    if fast.pcie != general.pcie {
        return Err(format!("{what}: PCIe {:?} vs {:?}", fast.pcie, general.pcie));
    }
    if fast.pcie_read_rate != general.pcie_read_rate {
        return Err(format!("{what}: PCIe read rate diverged"));
    }
    if fast.p50_latency_ns != general.p50_latency_ns
        || fast.p99_latency_ns != general.p99_latency_ns
    {
        return Err(format!("{what}: latency percentiles diverged"));
    }
    if fast.sched_steps != general.sched_steps {
        return Err(format!(
            "{what}: trajectories differ: {} vs {} steps",
            fast.sched_steps, general.sched_steps
        ));
    }
    if general.sched_events != general.sched_steps {
        return Err(format!("{what}: general path coalesced ({} events, {} steps)",
            general.sched_events, general.sched_steps));
    }
    if fast.sched_events > general.sched_events {
        return Err(format!(
            "{what}: fast path dispatched MORE events ({} vs {})",
            fast.sched_events, general.sched_events
        ));
    }
    Ok(())
}

/// Aggregate comparator for the **legacy-vs-canonical scheduler**
/// differential (PR 4): every virtual-time observable the figures and
/// reports consume must be bit-identical between the frozen
/// enqueue-order tie-break and the canonical `(time, tid, step)` key.
/// Equal-time ties commute: tied steps either touch disjoint simulation
/// state (order unobservable) or belong to threads in symmetric states,
/// where swapping them relabels which thread takes which FIFO slot —
/// so per-thread done-times are compared as a sorted multiset while
/// every aggregate (duration, rates, PCIe, latency stream) pins
/// exactly.
fn assert_same_virtual_world(
    a: &MsgRateResult,
    b: &MsgRateResult,
    what: &str,
) -> Result<(), String> {
    if a.duration != b.duration {
        return Err(format!("{what}: duration {} vs {}", a.duration, b.duration));
    }
    if a.messages != b.messages {
        return Err(format!("{what}: messages {} vs {}", a.messages, b.messages));
    }
    if a.mmsgs_per_sec != b.mmsgs_per_sec {
        return Err(format!("{what}: rate {} vs {}", a.mmsgs_per_sec, b.mmsgs_per_sec));
    }
    if a.pcie != b.pcie {
        return Err(format!("{what}: PCIe {:?} vs {:?}", a.pcie, b.pcie));
    }
    if a.pcie_read_rate != b.pcie_read_rate {
        return Err(format!("{what}: PCIe read rate diverged"));
    }
    if a.p50_latency_ns != b.p50_latency_ns || a.p99_latency_ns != b.p99_latency_ns {
        return Err(format!("{what}: latency percentiles diverged"));
    }
    if a.sched_steps != b.sched_steps {
        return Err(format!(
            "{what}: trajectories differ: {} vs {} steps",
            a.sched_steps, b.sched_steps
        ));
    }
    let mut da = a.thread_done.clone();
    let mut db = b.thread_done.clone();
    da.sort_unstable();
    db.sort_unstable();
    if da != db {
        return Err(format!("{what}: per-thread done-time multisets diverged"));
    }
    Ok(())
}

/// Comparator for the **partitioned-vs-sequential** differential: the
/// island-partitioned engine promises bit-identity on every observable,
/// including per-CQ occupancy high-water marks and per-thread
/// done-times in place (islands never relabel threads). Trajectories
/// (`sched_steps`) must match exactly; dispatches may only shrink — an
/// island's private horizon is coarser than the global one, so the
/// partitioned run may legally coalesce *more*.
fn assert_partitioned_exact(
    part: &MsgRateResult,
    seq: &MsgRateResult,
    what: &str,
) -> Result<(), String> {
    if part.duration != seq.duration {
        return Err(format!("{what}: duration {} vs {}", part.duration, seq.duration));
    }
    if part.thread_done != seq.thread_done {
        return Err(format!("{what}: per-thread done-times diverged"));
    }
    if part.messages != seq.messages {
        return Err(format!("{what}: messages {} vs {}", part.messages, seq.messages));
    }
    if part.mmsgs_per_sec != seq.mmsgs_per_sec {
        return Err(format!("{what}: rate {} vs {}", part.mmsgs_per_sec, seq.mmsgs_per_sec));
    }
    if part.pcie != seq.pcie {
        return Err(format!("{what}: PCIe {:?} vs {:?}", part.pcie, seq.pcie));
    }
    if part.pcie_read_rate != seq.pcie_read_rate {
        return Err(format!("{what}: PCIe read rate diverged"));
    }
    if part.p50_latency_ns != seq.p50_latency_ns || part.p99_latency_ns != seq.p99_latency_ns {
        return Err(format!("{what}: latency percentiles diverged"));
    }
    if part.cq_high_water != seq.cq_high_water {
        return Err(format!(
            "{what}: CQ high-water {:?} vs {:?}",
            part.cq_high_water, seq.cq_high_water
        ));
    }
    if part.sched_steps != seq.sched_steps {
        return Err(format!(
            "{what}: trajectories differ: {} vs {} steps",
            part.sched_steps, seq.sched_steps
        ));
    }
    if part.sched_events > seq.sched_events {
        return Err(format!(
            "{what}: partitioned dispatched MORE events ({} vs {})",
            part.sched_events, seq.sched_events
        ));
    }
    Ok(())
}

/// Run one config under the canonical scheduler (fast path on) and the
/// frozen legacy enqueue-order scheduler, returning both.
fn canonical_and_legacy(
    fabric: &Fabric,
    eps: &[scalable_ep::endpoints::ThreadEndpoint],
    cfg: MsgRateConfig,
) -> (MsgRateResult, MsgRateResult) {
    let canonical = Runner::new(fabric, eps, cfg).run();
    let legacy =
        Runner::new(fabric, eps, MsgRateConfig { use_legacy_scheduler: true, ..cfg }).run();
    (canonical, legacy)
}

#[test]
fn prop_uuar_accounting_conserves() {
    // allocated == used + wasted, for arbitrary build sequences.
    check("uuar-conservation", 0xA11C, 60, |rng, _| {
        let mut f = Fabric::connectx4();
        let ctx = f.open_ctx(Mlx5Env::default()).unwrap();
        let pd = f.alloc_pd(ctx).unwrap();
        let cq = f.create_cq(ctx, 16).unwrap();
        let n_ops = rng.range(1, 24);
        for _ in 0..n_ops {
            match rng.below(3) {
                0 => {
                    let _ = f.create_qp(pd, cq, QpCaps::default(), None);
                }
                1 => {
                    if let Ok(td) = f.alloc_td(ctx, TdInitAttr::independent()) {
                        let _ = f.create_qp(pd, cq, QpCaps::default(), Some(td));
                    }
                }
                _ => {
                    if let Ok(td) = f.alloc_td(ctx, TdInitAttr::paired()) {
                        let _ = f.create_qp(pd, cq, QpCaps::default(), Some(td));
                    }
                }
            }
        }
        let u = ResourceUsage::of_fabric(&f);
        if u.uuars_allocated != u.uuars_used + u.uuars_wasted() {
            return Err(format!("{u:?}"));
        }
        if u.uars_used > u.uars_allocated {
            return Err("more used than allocated UARs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_every_qp_maps_to_exactly_one_uuar() {
    check("qp-uuar-unique", 0xBEE, 40, |rng, _| {
        let mut f = Fabric::connectx4();
        let ctx = f.open_ctx(Mlx5Env::default()).unwrap();
        let pd = f.alloc_pd(ctx).unwrap();
        let cq = f.create_cq(ctx, 16).unwrap();
        for _ in 0..rng.range(1, 40) {
            let td = if rng.below(2) == 0 {
                Some(f.alloc_td(ctx, TdInitAttr::default()).unwrap())
            } else {
                None
            };
            f.create_qp(pd, cq, QpCaps::default(), td).unwrap();
        }
        // Count mappings from the UAR side; must equal the QP count.
        let c = f.ctx(ctx).unwrap();
        let mapped: usize = c.uars.iter().flat_map(|p| p.uuars.iter()).map(|u| u.qps.len()).sum();
        if mapped != f.qps.len() {
            return Err(format!("{} uuar mappings vs {} QPs", mapped, f.qps.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_server_fifo_monotone() {
    // Completion times are nondecreasing when arrivals are nondecreasing.
    check("server-fifo", 0x5EF, 200, |rng, _| {
        let mut s = Server::new();
        let mut now = 0u64;
        let mut last_end = 0u64;
        for _ in 0..rng.range(1, 50) {
            now += rng.below(500);
            let (_, end) = s.request(now, rng.range(1, 300));
            if end < last_end {
                return Err(format!("end {end} < previous {last_end}"));
            }
            last_end = end;
        }
        Ok(())
    });
}

#[test]
fn prop_lock_serializes_holds() {
    // Under arbitrary acquire patterns, total busy time >= sum of holds.
    check("lock-serializes", 0x10C, 100, |rng, _| {
        let mut l = SimLock::new(10, 20);
        let mut sum = 0u64;
        let mut now = 0u64;
        let mut last_release = 0u64;
        for i in 0..rng.range(2, 30) {
            now += rng.below(100);
            let hold = rng.range(1, 200);
            sum += hold;
            let (start, end) = l.acquire(now, (i % 4) as u32, hold);
            if start + hold != end {
                return Err("hold not honored".into());
            }
            if start < last_release.saturating_sub(0) && start != 0 {
                // starts must not precede the previous release
                if start < last_release {
                    return Err(format!("start {start} before prior release {last_release}"));
                }
            }
            last_release = end;
        }
        if l.busy() < sum {
            return Err(format!("busy {} < sum of holds {sum}", l.busy()));
        }
        Ok(())
    });
}

#[test]
fn prop_msgrate_determinism_and_completeness() {
    // Any sharing topology: every message completes, runs are
    // bit-deterministic, and throughput is finite and positive.
    let resources = [
        SharedResource::Buf,
        SharedResource::Ctx,
        SharedResource::Cq,
        SharedResource::Qp,
        SharedResource::Pd,
        SharedResource::Mr,
    ];
    check("msgrate-deterministic", 0xD15C, 24, |rng, _| {
        let res = *rng.choose(&resources);
        let ways = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let policy = EndpointPolicy::sharing(res, ways);
        let (fabric, eps) = policy.build_fresh(16).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig { msgs_per_thread: 512, features, ..Default::default() };
        let a = Runner::new(&fabric, &eps, cfg).run();
        let b = Runner::new(&fabric, &eps, cfg).run();
        if a.duration != b.duration {
            return Err(format!("nondeterministic: {} vs {}", a.duration, b.duration));
        }
        if a.messages < 16 * 512 {
            return Err(format!("lost messages: {}", a.messages));
        }
        if !(a.mmsgs_per_sec.is_finite() && a.mmsgs_per_sec > 0.0) {
            return Err(format!("bad rate {}", a.mmsgs_per_sec));
        }
        Ok(())
    });
}

#[test]
fn prop_fast_path_matches_general_path() {
    // The DES fast path (single-sharer coalescing, ring-buffer CQs,
    // indexed-heap scheduling) must produce *identical* virtual-time
    // results to the stepped general path across randomized sharing
    // topologies — bit-for-bit, not approximately.
    let resources = [
        SharedResource::Buf,
        SharedResource::Ctx,
        SharedResource::CtxTwoXQps,
        SharedResource::CtxSharing2,
        SharedResource::Pd,
        SharedResource::Mr,
        SharedResource::Cq,
        SharedResource::Qp,
    ];
    check("fast-vs-general", 0xFA57, 32, |rng, _| {
        let res = *rng.choose(&resources);
        let nthreads = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
        let ways_opts: Vec<u32> =
            [1u32, 2, 4, 8, 16].iter().copied().filter(|w| nthreads % w == 0).collect();
        let ways = *rng.choose(&ways_opts);
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let policy = EndpointPolicy::sharing(res, ways);
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 256 + rng.below(1024),
            features,
            ..Default::default()
        };
        let fast = Runner::new(&fabric, &eps, cfg).run();
        let general =
            Runner::new(&fabric, &eps, MsgRateConfig { force_general_path: true, ..cfg }).run();
        assert_bit_exact(
            &fast,
            &general,
            &format!("{res:?} {ways}-way x{nthreads}, {features:?}"),
        )
    });
}

#[test]
fn prop_fast_path_matches_general_path_fuzzed() {
    // Satellite fuzzer over the PR's three new fast paths: randomized
    // sharing topologies *and* QP depths *and* postlist sizes, thread
    // counts past the paper's 16-thread ceiling, and (via the symmetric
    // 1-way topologies) lock-step threads that tie at equal timestamps
    // every step. `SCEP_FUZZ_SEED` reseeds the sweep; the seed is echoed
    // for reproduction.
    let resources = [
        SharedResource::Buf,
        SharedResource::Ctx,
        SharedResource::CtxTwoXQps,
        SharedResource::CtxSharing2,
        SharedResource::Pd,
        SharedResource::Mr,
        SharedResource::Cq,
        SharedResource::Qp,
    ];
    check("fast-vs-general-fuzzed", fuzz_seed(0xC0A1E5CE), 28, |rng, _| {
        let res = *rng.choose(&resources);
        let nthreads = [1u32, 2, 4, 8, 16, 24, 32][rng.below(7) as usize];
        let ways_opts: Vec<u32> =
            [1u32, 2, 4, 8, 16].iter().copied().filter(|w| nthreads % w == 0).collect();
        let ways = *rng.choose(&ways_opts);
        let features = Features {
            postlist: [1u32, 2, 4, 8, 32][rng.below(5) as usize],
            unsignaled: [1u32, 4, 16, 64][rng.below(4) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let qp_depth = [16u32, 32, 64, 128, 256][rng.below(5) as usize];
        let policy = EndpointPolicy::sharing(res, ways);
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(512),
            qp_depth,
            features,
            ..Default::default()
        };
        let fast = Runner::new(&fabric, &eps, cfg).run();
        let general =
            Runner::new(&fabric, &eps, MsgRateConfig { force_general_path: true, ..cfg }).run();
        assert_bit_exact(
            &fast,
            &general,
            &format!("{res:?} {ways}-way x{nthreads} d={qp_depth}, {features:?}"),
        )
    });
}

#[test]
fn prop_fast_path_matches_general_path_categories_fuzzed() {
    // Same differential check over the six §VI endpoint categories,
    // including >16-thread builds; level-4 (shared-QP) categories must
    // additionally show zero coalescing — the fast paths stay off
    // exactly where the exactness proofs stop holding.
    check("fast-vs-general-categories", fuzz_seed(0xEDE7), 18, |rng, _| {
        let cat = *rng.choose(&Category::ALL);
        let policy = EndpointPolicy::preset(cat);
        let nthreads = [1u32, 4, 8, 16, 24, 32][rng.below(6) as usize];
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let mut f = Fabric::connectx4();
        let set = policy.build(&mut f, nthreads).map_err(|e| e.to_string())?;
        // Deliberately NOT forcing the shared-QP path for MpiThreads:
        // the zero-coalescing assertion below must pin the runner's own
        // sharing *detection* (qp_sharers/cq_sharers), not a config flag
        // that disables the fast path wholesale.
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(384),
            qp_depth: [32u32, 128][rng.below(2) as usize],
            features,
            ..Default::default()
        };
        let fast = Runner::new(&f, &set.threads, cfg).run();
        let general =
            Runner::new(&f, &set.threads, MsgRateConfig { force_general_path: true, ..cfg }).run();
        assert_bit_exact(&fast, &general, &format!("{cat} x{nthreads}, {features:?}"))?;
        if policy.shares_qp() && nthreads > 1 && fast.sched_events != fast.sched_steps {
            return Err(format!(
                "{cat}: shared-QP threads coalesced ({} events, {} steps)",
                fast.sched_events, fast.sched_steps
            ));
        }
        Ok(())
    });
}

/// Sample a random valid [`EndpointPolicy`] grid point for `nthreads`
/// threads: arbitrary CTX/PD/CQ grouping, all three QP provisioning
/// modes, all three uUAR mappings, every buffer layout, span MRs, and
/// both CQ depth rules — far beyond the six presets and eight sweeps.
fn random_policy(rng: &mut XorShift, nthreads: u32) -> EndpointPolicy {
    let divisors: Vec<u32> = (1..=nthreads).filter(|d| nthreads % d == 0).collect();
    let ctx_ways = *rng.choose(&divisors);
    let group_divs: Vec<u32> = (1..=ctx_ways).filter(|d| ctx_ways % d == 0).collect();
    let (qp, uar, cq) = match rng.below(4) {
        0 => {
            let w = *rng.choose(&group_divs);
            (QpProvision::Shared(Ways::Of(w)), UarMap::Static, Ways::Of(w))
        }
        1 => {
            let uar = if rng.below(2) == 0 { UarMap::Independent } else { UarMap::Paired };
            (QpProvision::TwoXEven, uar, Ways::Of(1))
        }
        _ => {
            let uar = match rng.below(3) {
                0 => UarMap::Independent,
                1 => UarMap::Paired,
                _ => UarMap::Static,
            };
            (QpProvision::PerThread, uar, Ways::Of(*rng.choose(&group_divs)))
        }
    };
    let buf = match rng.below(4) {
        0 => BufLayout::Aligned,
        1 => BufLayout::Packed,
        2 => BufLayout::Group(Ways::Of(*rng.choose(&divisors))),
        _ => BufLayout::SharedOne,
    };
    // Verbs constraint (policy validate): a shared QP's sharers must sit
    // in the QP's PD group, so PD ways must be a multiple of QP ways.
    let pd_ways = match qp {
        QpProvision::Shared(Ways::Of(w)) => {
            let ok: Vec<u32> = group_divs.iter().copied().filter(|d| d % w == 0).collect();
            *rng.choose(&ok)
        }
        _ => *rng.choose(&group_divs),
    };
    // Likewise a span MR must stay within one PD group and needs the
    // aligned per-thread buffer layout to cover every member.
    let mr = if matches!(buf, BufLayout::Aligned) && rng.below(4) == 0 {
        let spans: Vec<u32> = (1..=pd_ways).filter(|d| pd_ways % d == 0).collect();
        MrMap::SpanGroup(*rng.choose(&spans))
    } else {
        MrMap::PerThread
    };
    let cq_depth = if rng.below(2) == 0 {
        CqDepth::Scaled([2u32, 64][rng.below(2) as usize])
    } else {
        CqDepth::Fixed(1 + rng.below(64) as u32)
    };
    EndpointPolicy {
        ctx: Ways::Of(ctx_ways),
        qp,
        uar,
        cq,
        cq_depth,
        buf,
        pd: Ways::Of(pd_ways),
        mr,
        ..EndpointPolicy::default()
    }
}

#[test]
fn prop_fast_path_matches_general_path_policy_grid_fuzzed() {
    // Satellite fuzzer for the composable-policy API: random grid points
    // (not just the six presets / eight sweeps) must stay bit-identical
    // between the coalescing fast path and the stepped general path, and
    // multi-sharer shared-QP points must additionally show zero
    // coalescing — eligibility is derived from the built topology, so
    // this pins that the derivation never over-admits off-preset
    // configurations. `SCEP_FUZZ_SEED` reseeds; the seed is echoed.
    check("fast-vs-general-policy-grid", fuzz_seed(0x6D1D), 24, |rng, _| {
        let nthreads = [1u32, 2, 4, 8, 12, 16, 24][rng.below(7) as usize];
        let policy = random_policy(rng, nthreads);
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(384),
            qp_depth: [32u32, 128][rng.below(2) as usize],
            features,
            ..Default::default()
        };
        let fast = Runner::new(&fabric, &eps, cfg).run();
        let general =
            Runner::new(&fabric, &eps, MsgRateConfig { force_general_path: true, ..cfg }).run();
        assert_bit_exact(&fast, &general, &format!("policy '{policy}' x{nthreads}, {features:?}"))?;
        let multi_sharer_qp = match policy.qp {
            QpProvision::Shared(w) => w.resolve(policy.ctx.resolve(nthreads)) > 1,
            _ => false,
        };
        if multi_sharer_qp && fast.sched_events != fast.sched_steps {
            return Err(format!(
                "'{policy}': shared-QP threads coalesced ({} events, {} steps)",
                fast.sched_events, fast.sched_steps
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_symmetric_lockstep_threads_stay_bit_exact_and_coalesce() {
    // The per-CQ interaction bound's flagship case: identical independent
    // threads march in lock-step, tying at equal timestamps on every
    // step. Each thread's terminal drain (final window posted, only
    // private polls + Done remaining) must still coalesce — dispatched
    // events strictly below the general path's — while every
    // virtual-time observable stays bit-identical to the stepped path,
    // including past the paper's 16-thread ceiling.
    for nthreads in [8u32, 16, 32] {
        for features in [Features::all(), Features::conservative()] {
            let (fabric, eps) =
                EndpointPolicy::sharing(SharedResource::Ctx, 1).build_fresh(nthreads).unwrap();
            let cfg = MsgRateConfig { msgs_per_thread: 1024, features, ..Default::default() };
            let fast = Runner::new(&fabric, &eps, cfg).run();
            let general =
                Runner::new(&fabric, &eps, MsgRateConfig { force_general_path: true, ..cfg })
                    .run();
            assert_bit_exact(&fast, &general, &format!("lockstep x{nthreads}"))
                .unwrap_or_else(|e| panic!("{e}"));
            assert!(
                fast.sched_events < general.sched_events,
                "x{nthreads} {features:?}: symmetric ties defeated coalescing ({} vs {})",
                fast.sched_events,
                general.sched_events
            );
        }
    }
}

#[test]
fn prop_midrun_coalescing_beats_terminal_drain_baseline() {
    // PR-4 acceptance: with the enqueue-order-invariant key, symmetric
    // lock-step threads coalesce *mid-run* poll windows, not just the
    // terminal drain. Against the PR-2 rule (terminal drain only,
    // preserved behind `restrict_coalesce_to_terminal_drain`) the
    // dispatched-event count must strictly drop — i.e. coalesced_steps
    // strictly grows — at 16 and past the paper's ceiling at 32
    // threads, while every observable (same scheduler, both guards
    // exact) stays bit-identical including per-thread done-times.
    for nthreads in [16u32, 32] {
        for features in [Features::all(), Features::conservative()] {
            let (fabric, eps) =
                EndpointPolicy::sharing(SharedResource::Ctx, 1).build_fresh(nthreads).unwrap();
            let cfg = MsgRateConfig { msgs_per_thread: 1024, features, ..Default::default() };
            let full = Runner::new(&fabric, &eps, cfg).run();
            let terminal = Runner::new(
                &fabric,
                &eps,
                MsgRateConfig { restrict_coalesce_to_terminal_drain: true, ..cfg },
            )
            .run();
            assert_eq!(full.duration, terminal.duration, "x{nthreads} {features:?}");
            assert_eq!(full.thread_done, terminal.thread_done, "x{nthreads} {features:?}");
            assert_eq!(full.pcie, terminal.pcie, "x{nthreads} {features:?}");
            assert_eq!(full.sched_steps, terminal.sched_steps, "x{nthreads} {features:?}");
            let coalesced_full = full.sched_steps - full.sched_events;
            let coalesced_terminal = terminal.sched_steps - terminal.sched_events;
            assert!(
                coalesced_full > coalesced_terminal,
                "x{nthreads} {features:?}: mid-run windows did not coalesce \
                 ({coalesced_full} vs terminal-only {coalesced_terminal})"
            );
        }
    }
}

#[test]
fn prop_legacy_vs_canonical_on_golden_figure_cells() {
    // The PR-4 tentpole's acceptance pin: over every cell of the golden
    // fig2/fig9/fig11 tables (the byte-pinned `--quick` set, at a
    // trimmed message count), the canonical tie-break must reproduce
    // the frozen enqueue-order scheduler's virtual-time results
    // bit-for-bit — the golden tables are rates and topology-derived
    // accounting, so table bytes cannot move either.
    let msgs = 2048;
    // Fig 2(b): the two state-of-the-art extremes across the thread
    // sweep.
    for n in [1u32, 2, 4, 8, 16] {
        for cat in [Category::MpiEverywhere, Category::MpiThreads] {
            let mut f = Fabric::connectx4();
            let set = EndpointPolicy::preset(cat).build(&mut f, n).unwrap();
            let cfg = MsgRateConfig { msgs_per_thread: msgs, ..Default::default() };
            let (canonical, legacy) = canonical_and_legacy(&f, &set.threads, cfg);
            assert_same_virtual_world(&canonical, &legacy, &format!("fig2 {cat} x{n}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
    // Fig 9 (CQ sharing) and Fig 11 (QP sharing): 16 threads, the full
    // x-way sweep under every feature set of the table columns.
    for (fig, res) in [("fig9", SharedResource::Cq), ("fig11", SharedResource::Qp)] {
        for ways in [1u32, 2, 4, 8, 16] {
            for fs in FeatureSet::ALL_SETS.iter() {
                let (fabric, eps) =
                    EndpointPolicy::sharing(res, ways).build_fresh(16).unwrap();
                let cfg = MsgRateConfig {
                    msgs_per_thread: msgs,
                    features: fs.features(),
                    ..Default::default()
                };
                let (canonical, legacy) = canonical_and_legacy(&fabric, &eps, cfg);
                assert_same_virtual_world(
                    &canonical,
                    &legacy,
                    &format!("{fig} {ways}-way {:?}", fs.features()),
                )
                .unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

#[test]
fn prop_legacy_vs_canonical_scheduler_fuzzed() {
    // Satellite fuzzer for the canonical tie-break: across random policy
    // grid points x thread counts x features x QP depths x postlist
    // sizes, the frozen enqueue-order scheduler and the canonical
    // scheduler (fast path on) must agree on every virtual-time
    // aggregate bit-for-bit — equal-time ties commute; only the
    // dispatch order is allowed to differ. `SCEP_FUZZ_SEED` reseeds the
    // sweep; the seed is echoed for reproduction.
    check("legacy-vs-canonical", fuzz_seed(0x71EB_4EA4), 24, |rng, _| {
        let nthreads = [1u32, 2, 4, 8, 12, 16, 24, 32][rng.below(8) as usize];
        let policy = random_policy(rng, nthreads);
        let features = Features {
            postlist: [1u32, 2, 4, 32][rng.below(4) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(512),
            qp_depth: [32u32, 128][rng.below(2) as usize],
            features,
            ..Default::default()
        };
        let (canonical, legacy) = canonical_and_legacy(&fabric, &eps, cfg);
        assert_same_virtual_world(
            &canonical,
            &legacy,
            &format!("policy '{policy}' x{nthreads}, {features:?}"),
        )?;
        // The legacy path is pinned one-event-per-step; the canonical
        // fast path may only ever dispatch fewer events.
        if legacy.sched_events != legacy.sched_steps {
            return Err(format!("legacy path coalesced ({legacy:?})"));
        }
        if canonical.sched_events > legacy.sched_events {
            return Err(format!(
                "canonical dispatched MORE events ({} vs {})",
                canonical.sched_events, legacy.sched_events
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_partitioned_matches_sequential_on_golden_cells() {
    // Tentpole acceptance pin: over every cell of the golden fig2/fig9/
    // fig11 tables (trimmed message count) plus the golden pool table's
    // scalable rows, the island-partitioned engine must reproduce the
    // sequential run bit-for-bit — whether a speculation validated or
    // the run fell back, the contract is unconditional.
    let msgs = 2048;
    for n in [1u32, 2, 4, 8, 16] {
        for cat in [Category::MpiEverywhere, Category::MpiThreads] {
            let mut f = Fabric::connectx4();
            let set = EndpointPolicy::preset(cat).build(&mut f, n).unwrap();
            let cfg = MsgRateConfig { msgs_per_thread: msgs, ..Default::default() };
            let seq = Runner::new(&f, &set.threads, cfg).run();
            let (part, _) = Runner::new(&f, &set.threads, cfg).run_partitioned_with(4);
            assert_partitioned_exact(&part, &seq, &format!("fig2 {cat} x{n}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
    for (fig, res) in [("fig9", SharedResource::Cq), ("fig11", SharedResource::Qp)] {
        for ways in [1u32, 2, 4, 8, 16] {
            for fs in FeatureSet::ALL_SETS.iter() {
                let (fabric, eps) = EndpointPolicy::sharing(res, ways).build_fresh(16).unwrap();
                let cfg = MsgRateConfig {
                    msgs_per_thread: msgs,
                    features: fs.features(),
                    ..Default::default()
                };
                let seq = Runner::new(&fabric, &eps, cfg).run();
                let (part, _) = Runner::new(&fabric, &eps, cfg).run_partitioned_with(4);
                assert_partitioned_exact(
                    &part,
                    &seq,
                    &format!("{fig} {ways}-way {:?}", fs.features()),
                )
                .unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
    // Golden pool cells: 16 streams over a 5-slot scalable pool, run
    // directly on the pooled topology under both stateless placements.
    for strategy in [MapStrategy::RoundRobin, MapStrategy::Hashed] {
        let (fabric, pool) = EndpointPool::build_fresh(&EndpointPolicy::scalable(), 5).unwrap();
        let mut mapper = VciMapper::new(strategy, 5);
        for t in 0..16 {
            mapper.assign(Stream::of_thread(t));
        }
        let threads = pooled_threads(&pool, &mapper);
        let cfg = MsgRateConfig { msgs_per_thread: msgs, ..Default::default() };
        let seq = Runner::new(&fabric, &threads, cfg).run();
        let (part, _) = Runner::new(&fabric, &threads, cfg).run_partitioned_with(4);
        assert_partitioned_exact(&part, &seq, &format!("pool 5/16 {strategy}"))
            .unwrap_or_else(|e| panic!("{e}"));
    }
}

#[test]
fn prop_partitioned_default_workers_matches_sequential() {
    // Same differential under the *process* worker budget
    // (`run_partitioned` reads `par::workers`; CI runs this leg under a
    // SCEP_WORKERS=1 vs 4 matrix), so the engine is exercised at
    // whatever parallelism the environment provides, including the
    // forced-sequential workers=1 degenerate case.
    let (fabric, eps) = EndpointPolicy::sharing(SharedResource::Ctx, 1).build_fresh(16).unwrap();
    let cfg = MsgRateConfig { msgs_per_thread: 2048, ..Default::default() };
    let seq = Runner::new(&fabric, &eps, cfg).run();
    let part = Runner::new(&fabric, &eps, cfg).run_partitioned();
    assert_partitioned_exact(&part, &seq, "default-workers x16").unwrap_or_else(|e| panic!("{e}"));
}

#[test]
fn prop_partitioned_matches_sequential_fuzzed() {
    // Tentpole fuzzer: random policy grid points x thread counts x
    // features x worker budgets — and pooled topologies under every map
    // strategy — must stay bit-identical between the island-partitioned
    // engine and the sequential runner on every observable.
    // `SCEP_FUZZ_SEED` reseeds the sweep; the seed is echoed.
    check("partitioned-vs-sequential", fuzz_seed(0x15_1A2D), 20, |rng, _| {
        let nthreads = [2u32, 4, 8, 12, 16, 24][rng.below(6) as usize];
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let cfg = MsgRateConfig {
            msgs_per_thread: 256 + rng.below(512),
            qp_depth: [32u32, 128][rng.below(2) as usize],
            features,
            ..Default::default()
        };
        let nworkers = [2usize, 4][rng.below(2) as usize];
        let (fabric, threads, what) = if rng.below(3) == 0 {
            // Pooled topology: more streams than slots, any placement.
            let pool_size = 1 + rng.below(5) as u32;
            let policy = random_policy(rng, pool_size);
            let strategy = match rng.below(3) {
                0 => MapStrategy::RoundRobin,
                1 => MapStrategy::Hashed,
                _ => MapStrategy::adaptive(),
            };
            let (fabric, pool) =
                EndpointPool::build_fresh(&policy, pool_size).map_err(|e| e.to_string())?;
            let mut mapper = VciMapper::new(strategy, pool_size);
            for t in 0..nthreads {
                mapper.assign(Stream::of_thread(t));
            }
            let threads = pooled_threads(&pool, &mapper);
            (fabric, threads, format!("pool '{policy}' {pool_size}/{nthreads} {strategy}"))
        } else {
            let policy = random_policy(rng, nthreads);
            let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
            (fabric, eps, format!("policy '{policy}' x{nthreads}"))
        };
        let seq = Runner::new(&fabric, &threads, cfg).run();
        let (part, stats) = Runner::new(&fabric, &threads, cfg).run_partitioned_with(nworkers);
        assert_partitioned_exact(&part, &seq, &format!("{what}, {features:?}, w={nworkers}"))?;
        if stats.parallel && stats.islands < 2 {
            return Err(format!("{what}: claims parallel with {} islands", stats.islands));
        }
        Ok(())
    });
}

#[test]
fn prop_snapshot_fork_bit_exact_fuzzed() {
    // Snapshot-fork property: clone a runner mid-run at a random step,
    // finish the original and the clone independently, and both must
    // report results bit-identical to an uninterrupted closed-loop run —
    // rates, durations, PCIe, CQ high-water occupancy, per-thread
    // done-times. This is the primitive under island speculation and
    // sweep memoization. `SCEP_FUZZ_SEED` reseeds; the seed is echoed.
    check("snapshot-fork", fuzz_seed(0xF0_4C), 20, |rng, _| {
        let nthreads = [1u32, 2, 4, 8, 16][rng.below(5) as usize];
        let policy = random_policy(rng, nthreads);
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(512),
            features,
            ..Default::default()
        };
        let reference = Runner::new(&fabric, &eps, cfg).run();
        let mut a = Runner::new(&fabric, &eps, cfg);
        a.ensure_started();
        let k = rng.below(200);
        for _ in 0..k {
            if !a.step_one() {
                break;
            }
        }
        let b = a.fork();
        let drive = |mut r: Runner| {
            while r.step_one() {}
            r.finish()
        };
        let what = format!("policy '{policy}' x{nthreads} fork@{k}, {features:?}");
        assert_partitioned_exact(&drive(a), &reference, &format!("{what} (original)"))?;
        assert_partitioned_exact(&drive(b), &reference, &format!("{what} (fork)"))?;
        Ok(())
    });
}

#[test]
fn prop_memoized_sweep_matches_scratch() {
    // Memoized-sweep acceptance: per-cell bit-identity against
    // from-scratch runs (dispatch counts included — the continuation
    // replays the identical schedule) and, since these shapes admit a
    // pause point, strictly fewer executed scheduler steps.
    for (nthreads, targets) in [(16u32, [512u64, 1024, 2048]), (8, [256, 512, 768])] {
        let (fabric, eps) =
            EndpointPolicy::sharing(SharedResource::Ctx, 1).build_fresh(nthreads).unwrap();
        let cfg = MsgRateConfig::default();
        let sweep = Runner::sweep_msgs(&fabric, &eps, cfg, &targets);
        assert!(sweep.prefix_steps > 0, "x{nthreads}: no pause point found");
        assert!(
            sweep.memo_steps < sweep.scratch_steps,
            "x{nthreads}: memoization saved nothing ({} vs {} steps)",
            sweep.memo_steps,
            sweep.scratch_steps
        );
        for (&target, memoized) in targets.iter().zip(&sweep.results) {
            let scratch =
                Runner::new(&fabric, &eps, MsgRateConfig { msgs_per_thread: target, ..cfg })
                    .run();
            let what = format!("x{nthreads} target {target}");
            assert_eq!(memoized.duration, scratch.duration, "{what}");
            assert_eq!(memoized.thread_done, scratch.thread_done, "{what}");
            assert_eq!(memoized.mmsgs_per_sec, scratch.mmsgs_per_sec, "{what}");
            assert_eq!(memoized.pcie, scratch.pcie, "{what}");
            assert_eq!(memoized.p50_latency_ns, scratch.p50_latency_ns, "{what}");
            assert_eq!(memoized.p99_latency_ns, scratch.p99_latency_ns, "{what}");
            assert_eq!(memoized.cq_high_water, scratch.cq_high_water, "{what}");
            assert_eq!(memoized.sched_steps, scratch.sched_steps, "{what}");
            assert_eq!(memoized.sched_events, scratch.sched_events, "{what}");
        }
    }
}

#[test]
fn prop_pooled_dedicated_matches_per_thread_path_fuzzed() {
    // VCI pool axis, identity leg: `Dedicated` over a full-size pool of
    // ANY policy grid point must reproduce the historical per-thread
    // path bit-for-bit — every virtual-time observable plus the engine
    // diagnostics (the pool layer may not perturb fast-path
    // eligibility). `SCEP_FUZZ_SEED` reseeds; the seed is echoed.
    check("pool-dedicated-identity", fuzz_seed(0xD1_CE0), 16, |rng, _| {
        let nthreads = [1u32, 2, 4, 8, 12, 16, 24][rng.below(7) as usize];
        let policy = random_policy(rng, nthreads);
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(256),
            features,
            ..Default::default()
        };
        let (fabric, eps) = policy.build_fresh(nthreads).map_err(|e| e.to_string())?;
        let direct = Runner::new(&fabric, &eps, cfg).run();
        let pooled = run_pooled(&policy, nthreads, nthreads, MapStrategy::Dedicated, cfg)
            .map_err(|e| e.to_string())?;
        let what = format!("policy '{policy}' x{nthreads}, {features:?}");
        if pooled.result.duration != direct.duration {
            return Err(format!("{what}: duration diverged"));
        }
        if pooled.result.thread_done != direct.thread_done {
            return Err(format!("{what}: per-thread done-times diverged"));
        }
        if pooled.result.mmsgs_per_sec != direct.mmsgs_per_sec {
            return Err(format!("{what}: rate diverged"));
        }
        if pooled.result.pcie != direct.pcie {
            return Err(format!("{what}: PCIe counters diverged"));
        }
        if pooled.result.p50_latency_ns != direct.p50_latency_ns
            || pooled.result.p99_latency_ns != direct.p99_latency_ns
        {
            return Err(format!("{what}: latency percentiles diverged"));
        }
        if pooled.result.sched_events != direct.sched_events
            || pooled.result.sched_steps != direct.sched_steps
        {
            return Err(format!(
                "{what}: engine diagnostics diverged ({}/{} vs {}/{})",
                pooled.result.sched_events,
                pooled.result.sched_steps,
                direct.sched_events,
                direct.sched_steps
            ));
        }
        if pooled.migrations != 0 {
            return Err(format!("{what}: dedicated mapping migrated"));
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_fast_path_matches_general_path_fuzzed() {
    // VCI pool axis, sharing leg: random policy grid points built as
    // bounded pools with more streams than slots must stay bit-exact
    // between the coalescing fast path and the stepped general path
    // (eligibility is re-derived from the pooled topology), and the
    // whole pooled run — Hashed/RoundRobin placement included — must be
    // a pure function of its inputs (rerun => bit-identical), which is
    // what keeps the sweep reproducible under `SCEP_FUZZ_SEED`
    // reseeding. `Adaptive` additionally pins that the probe/rebalance
    // trajectory is engine-path-independent (same loads either way).
    check("pool-fast-vs-general", fuzz_seed(0x900_1ED), 18, |rng, _| {
        let pool_size = [1u32, 2, 3, 4, 5, 8][rng.below(6) as usize];
        let policy = random_policy(rng, pool_size);
        let nstreams = pool_size + rng.below(17) as u32;
        let strategy = match rng.below(3) {
            0 => MapStrategy::RoundRobin,
            1 => MapStrategy::Hashed,
            _ => MapStrategy::Adaptive { occupancy: 1 + rng.below(4) as u32 },
        };
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(256),
            qp_depth: [32u32, 128][rng.below(2) as usize],
            features,
            ..Default::default()
        };
        let what =
            format!("policy '{policy}' pool {pool_size} x{nstreams} streams, {strategy}");
        let fast = run_pooled(&policy, nstreams, pool_size, strategy, cfg)
            .map_err(|e| e.to_string())?;
        let general = run_pooled(
            &policy,
            nstreams,
            pool_size,
            strategy,
            MsgRateConfig { force_general_path: true, ..cfg },
        )
        .map_err(|e| e.to_string())?;
        assert_bit_exact(&fast.result, &general.result, &what)?;
        if fast.loads != general.loads || fast.migrations != general.migrations {
            return Err(format!("{what}: mapping depends on the engine path"));
        }
        let again = run_pooled(&policy, nstreams, pool_size, strategy, cfg)
            .map_err(|e| e.to_string())?;
        if again.result.duration != fast.result.duration
            || again.result.thread_done != fast.result.thread_done
            || again.loads != fast.loads
        {
            return Err(format!("{what}: pooled run is not deterministic"));
        }
        Ok(())
    });
}

#[test]
fn prop_fast_path_matches_general_path_multi_endpoint() {
    // Stencil-shaped threads (two QPs round-robin into one CQ) exercise
    // the multi-endpoint fast path; rank-grouped runs must fall back to
    // the general path and still agree trivially.
    use scalable_ep::apps::stencil::DEFAULT_HALO_BYTES;
    use scalable_ep::apps::StencilBench;
    use scalable_ep::coordinator::JobSpec;

    for cat in [Category::MpiEverywhere, Category::Dynamic, Category::MpiThreads] {
        let s = StencilBench::new(JobSpec::new(2, 4), cat, DEFAULT_HALO_BYTES).unwrap();
        let cfg = MsgRateConfig {
            msgs_per_thread: 512,
            msg_size: DEFAULT_HALO_BYTES,
            features: Features::conservative(),
            force_shared_qp_path: s.policy.shares_qp(),
            ..Default::default()
        };
        let fast = Runner::new_multi(&s.fabric, &s.threads, cfg).run();
        let general = Runner::new_multi(
            &s.fabric,
            &s.threads,
            MsgRateConfig { force_general_path: true, ..cfg },
        )
        .run();
        assert_eq!(fast.duration, general.duration, "{cat}");
        assert_eq!(fast.thread_done, general.thread_done, "{cat}");
        assert_eq!(fast.pcie, general.pcie, "{cat}");
    }
}

#[test]
fn prop_more_sharing_never_increases_uuars() {
    // Hardware resource usage is monotone nonincreasing in sharing degree.
    for res in [SharedResource::Ctx, SharedResource::Cq, SharedResource::Qp] {
        let mut prev = u32::MAX;
        for ways in [1u32, 2, 4, 8, 16] {
            let (f, _) = EndpointPolicy::sharing(res, ways).build_fresh(16).unwrap();
            let u = ResourceUsage::of_fabric(&f);
            assert!(
                u.uuars_allocated <= prev,
                "{res:?} {ways}-way: {} uUARs > previous {prev}",
                u.uuars_allocated
            );
            prev = u.uuars_allocated;
        }
    }
}

#[test]
fn prop_category_rate_vs_resources_pareto() {
    // Check the headline tradeoff is a proper frontier: every category
    // with fewer uUARs than another must not also be strictly faster than
    // every cheaper configuration (i.e. the six points form the paper's
    // performance/resource tradeoff, not noise).
    let mut points = Vec::new();
    for cat in Category::ALL {
        let policy = EndpointPolicy::preset(cat);
        let mut f = Fabric::connectx4();
        let set = policy.build(&mut f, 16).unwrap();
        let cfg = MsgRateConfig {
            msgs_per_thread: 4096,
            features: Features::conservative(),
            force_shared_qp_path: policy.shares_qp(),
            ..Default::default()
        };
        let r = Runner::new(&f, &set.threads, cfg).run();
        let u = ResourceUsage::of_set(&f, &set);
        points.push((cat, u.uuars_allocated, r.mmsgs_per_sec));
    }
    // MPI everywhere must be the most expensive; MPI+threads the slowest.
    let max_uuars = points.iter().map(|p| p.1).max().unwrap();
    assert_eq!(points[0].1, max_uuars);
    let min_rate = points.iter().map(|p| p.2).fold(f64::INFINITY, f64::min);
    assert!((points[5].2 - min_rate).abs() < 1e-9, "MPI+threads should be slowest");
}

#[test]
fn appendix_b_fig16_assignment_example() {
    // Fig 16: a CTX with six static uUARs, two of them low-latency
    // (uUAR4-5). Seven QPs and three TDs are assigned:
    //   QP0 -> uUAR4, QP1 -> uUAR5 (low latency, one QP each)
    //   QP2..QP6 -> uUAR1,2,3,1,2 (medium latency, round robin)
    //   TD0/TD1 -> the two uUARs of one fresh dynamic page; TD2 -> the
    //   first uUAR of a second dynamic page.
    let mut f = Fabric::connectx4();
    let ctx = f
        .open_ctx(Mlx5Env { total_uuars: 6, num_low_lat_uuars: 2, shut_up_bf: false })
        .unwrap();
    let pd = f.alloc_pd(ctx).unwrap();
    let cq = f.create_cq(ctx, 16).unwrap();
    let slot = |f: &Fabric, qp| {
        let u = f.qp(qp).unwrap().uuar;
        u.page * 2 + u.slot as u32
    };
    let qps: Vec<_> =
        (0..7).map(|_| f.create_qp(pd, cq, QpCaps::default(), None).unwrap()).collect();
    let got: Vec<u32> = qps.iter().map(|&q| slot(&f, q)).collect();
    assert_eq!(got, vec![4, 5, 1, 2, 3, 1, 2]);

    let t0 = f.alloc_td(ctx, TdInitAttr::paired()).unwrap();
    let t1 = f.alloc_td(ctx, TdInitAttr::paired()).unwrap();
    let t2 = f.alloc_td(ctx, TdInitAttr::paired()).unwrap();
    let (u0, u1, u2) = (f.td(t0).unwrap().uuar, f.td(t1).unwrap().uuar, f.td(t2).unwrap().uuar);
    assert_eq!(u0.page, 3, "first dynamic page follows the 3 static pages");
    assert_eq!((u0.slot, u1.slot), (0, 1));
    assert_eq!(u0.page, u1.page);
    assert_eq!((u2.page, u2.slot), (4, 0));
}

#[test]
fn appendix_b_env_knobs_reshape_the_ctx() {
    // MLX5_TOTAL_UUARS / MLX5_NUM_LOW_LAT_UUARS change the static layout.
    let mut f = Fabric::connectx4();
    let ctx = f
        .open_ctx(Mlx5Env { total_uuars: 32, num_low_lat_uuars: 8, shut_up_bf: false })
        .unwrap();
    let c = f.ctx(ctx).unwrap();
    assert_eq!(c.static_uar_pages(), 16);
    let pd = f.alloc_pd(ctx).unwrap();
    let cq = f.create_cq(ctx, 16).unwrap();
    // 8 QPs fill the low-latency range 24..31 before any medium reuse.
    let mut slots = Vec::new();
    for _ in 0..8 {
        let qp = f.create_qp(pd, cq, QpCaps::default(), None).unwrap();
        let u = f.qp(qp).unwrap().uuar;
        slots.push(u.page * 2 + u.slot as u32);
    }
    assert_eq!(slots, (24..32).collect::<Vec<u32>>());
}

#[test]
fn prop_failure_injection_destroy_rebuild() {
    // Destroy/rebuild churn keeps accounting consistent (failure
    // injection over the object lifecycle).
    check("destroy-rebuild", 0xDEAD, 30, |rng, _| {
        let mut f = Fabric::connectx4();
        let ctx = f.open_ctx(Mlx5Env::default()).unwrap();
        let pd = f.alloc_pd(ctx).unwrap();
        let cq = f.create_cq(ctx, 16).unwrap();
        let mut live = Vec::new();
        for _ in 0..rng.range(5, 40) {
            if rng.below(3) == 0 && !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                let qp = live.swap_remove(idx);
                f.destroy_qp(qp).map_err(|e| e.to_string())?;
            } else {
                live.push(f.create_qp(pd, cq, QpCaps::default(), None).unwrap());
            }
        }
        let u = ResourceUsage::of_fabric(&f);
        if u.qps as usize != live.len() {
            return Err(format!("{} live QPs accounted, expected {}", u.qps, live.len()));
        }
        // uUAR mappings must match live QPs exactly.
        let c = f.ctx(ctx).unwrap();
        let mapped: usize = c.uars.iter().flat_map(|p| p.uuars.iter()).map(|u| u.qps.len()).sum();
        if mapped != live.len() {
            return Err(format!("{mapped} mappings vs {} live", live.len()));
        }
        Ok(())
    });
}
