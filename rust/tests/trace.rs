//! Acceptance tests for the deterministic trace layer (ISSUE 10): a
//! disabled sink leaves every golden fixture byte-unchanged and never
//! perturbs a virtual-time observable, and an enabled sink's Chrome
//! trace-event stream is bit-identical across the sequential fast path,
//! the forced general path, and the island-partitioned engine at any
//! worker count — the canonical `(time, tid, step)` key plus the
//! keep-smallest compaction make emission order unobservable.

use scalable_ep::bench::{Features, MsgRateConfig, MsgRateResult, Runner, SharedResource};
use scalable_ep::endpoints::{Category, EndpointPolicy, ThreadEndpoint};
use scalable_ep::experiment::Json;
use scalable_ep::testing::check;
use scalable_ep::trace::{render_chrome, snapshot, SnapshotInput, Trace};
use scalable_ep::vci::{pooled_threads, EndpointPool, MapStrategy, Stream, VciMapper};
use scalable_ep::verbs::Fabric;
use scalable_ep::workload::drive::run_cell_traced;
use scalable_ep::workload::Scenario;

/// Seed for the randomized differential fuzzer: `SCEP_FUZZ_SEED=<u64>`
/// overrides the fixed default; the seed is echoed for reproduction
/// (same contract as `tests/properties.rs`).
fn fuzz_seed(default: u64) -> u64 {
    match std::env::var("SCEP_FUZZ_SEED") {
        Ok(s) => {
            let seed = s
                .trim()
                .parse::<u64>()
                .unwrap_or_else(|e| panic!("SCEP_FUZZ_SEED={s:?} is not a u64: {e}"));
            eprintln!("[trace] SCEP_FUZZ_SEED={seed} (reproduce with this env var)");
            seed
        }
        Err(_) => default,
    }
}

/// Render the canonical Chrome stream of a finished traced run (no VCI
/// dimension — these cells have no mapper).
fn chrome_of(result: &mut MsgRateResult, label: &str) -> String {
    assert!(result.trace.is_some(), "{label}: traced run carries no buffer");
    render_chrome(&Trace::assemble(label, result.trace.take(), Vec::new()))
}

/// Every virtual-time observable must agree bit-for-bit; `sched_steps`
/// (the trajectory length) too. Dispatch counts are deliberately NOT
/// compared — they are engine diagnostics and legitimately differ
/// across strategies.
fn assert_observables_equal(a: &MsgRateResult, b: &MsgRateResult, what: &str) {
    assert_eq!(a.duration, b.duration, "{what}: duration");
    assert_eq!(a.thread_done, b.thread_done, "{what}: per-thread done-times");
    assert_eq!(a.messages, b.messages, "{what}: messages");
    assert_eq!(a.mmsgs_per_sec, b.mmsgs_per_sec, "{what}: rate");
    assert_eq!(a.pcie, b.pcie, "{what}: PCIe counters");
    assert_eq!(a.p50_latency_ns, b.p50_latency_ns, "{what}: p50");
    assert_eq!(a.p99_latency_ns, b.p99_latency_ns, "{what}: p99");
    assert_eq!(a.cq_high_water, b.cq_high_water, "{what}: CQ high-water");
    assert_eq!(a.sched_steps, b.sched_steps, "{what}: trajectory length");
    assert_eq!(a.lock_contended, b.lock_contended, "{what}: lock contention");
}

/// The golden cell shapes the figures pin, at a trimmed message count:
/// fig2's two state-of-the-art extremes, fig9's 16-way CQ, fig11's
/// 16-way QP, and the pool figure's 5-slot scalable cell.
fn golden_cells() -> Vec<(String, Fabric, Vec<ThreadEndpoint>)> {
    let mut cells = Vec::new();
    for cat in [Category::MpiEverywhere, Category::MpiThreads] {
        let mut f = Fabric::connectx4();
        let set = EndpointPolicy::preset(cat).build(&mut f, 16).unwrap();
        cells.push((format!("fig2 {cat} x16"), f, set.threads));
    }
    for (fig, res) in [("fig9", SharedResource::Cq), ("fig11", SharedResource::Qp)] {
        let (fabric, eps) = EndpointPolicy::sharing(res, 16).build_fresh(16).unwrap();
        cells.push((format!("{fig} 16-way x16"), fabric, eps));
    }
    let (fabric, pool) = EndpointPool::build_fresh(&EndpointPolicy::scalable(), 5).unwrap();
    let mut mapper = VciMapper::new(MapStrategy::Hashed, 5);
    for t in 0..16 {
        mapper.assign(Stream::of_thread(t));
    }
    let threads = pooled_threads(&pool, &mapper);
    cells.push(("pool 5/16 hashed".to_string(), fabric, threads));
    cells
}

#[test]
fn prop_tracing_off_is_byte_identical() {
    // Leg 1: the disabled sink (the default) leaves every committed
    // golden fixture byte-unchanged. Fixtures are CI-blessed
    // (tests/fixtures/README.md); absent ones are skipped with a note —
    // figures_shape.rs owns first-generation.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    for name in ["fig2", "fig9", "fig11", "pool", "fig12", "fig14", "workloads"] {
        let path = dir.join(format!("{name}_quick.golden.txt"));
        let Ok(golden) = std::fs::read(&path) else {
            eprintln!("[trace] {name}: no committed fixture yet; leg arms once CI blesses");
            continue;
        };
        let bytes = scalable_ep::figures::render_bytes(name, true).expect("known figure");
        assert_eq!(bytes, golden, "{name}: disabled sink moved the golden table bytes");
    }

    // Leg 2: enabling the sink is pure observation — every virtual-time
    // observable of a traced run equals the untraced run's bit-for-bit,
    // and the untraced result carries no buffer, on every golden cell
    // shape.
    let cfg = MsgRateConfig { msgs_per_thread: 2048, ..Default::default() };
    for (what, fabric, eps) in golden_cells() {
        let plain = Runner::new(&fabric, &eps, cfg).run();
        assert!(plain.trace.is_none(), "{what}: untraced run grew a trace buffer");
        let mut runner = Runner::new(&fabric, &eps, cfg);
        runner.set_tracing(true);
        let traced = runner.run();
        assert!(traced.trace.is_some(), "{what}: traced run lost its buffer");
        assert_observables_equal(&traced, &plain, &what);
    }
}

#[test]
fn traced_stream_is_identical_across_execution_strategies_on_golden_cells() {
    // The tentpole's hard requirement, pinned on the golden cell shapes:
    // the rendered Chrome stream of the sequential fast path, the forced
    // general path, and the partitioned engine at 1 and 4 workers must
    // be the same bytes.
    let cfg = MsgRateConfig { msgs_per_thread: 2048, ..Default::default() };
    for (what, fabric, eps) in golden_cells() {
        let traced_run = |cfg: MsgRateConfig| {
            let mut r = Runner::new(&fabric, &eps, cfg);
            r.set_tracing(true);
            r
        };
        let mut seq = traced_run(cfg).run();
        let reference = chrome_of(&mut seq, &what);
        let mut general =
            traced_run(MsgRateConfig { force_general_path: true, ..cfg }).run();
        assert_eq!(chrome_of(&mut general, &what), reference, "{what}: general path drifted");
        for workers in [1usize, 4] {
            let (mut part, _) = traced_run(cfg).run_partitioned_with(workers);
            assert_eq!(
                chrome_of(&mut part, &what),
                reference,
                "{what}: partitioned stream drifted at {workers} workers"
            );
            assert_observables_equal(&part, &seq, &format!("{what} w={workers}"));
        }
    }
}

#[test]
fn prop_traced_streams_identical_sequential_vs_partitioned_fuzzed() {
    // Fuzzed differential over random sharing topologies x features x
    // message counts x worker budgets: sequential vs forced-general vs
    // `run_partitioned_with` trace streams must stay byte-identical.
    // `SCEP_FUZZ_SEED` reseeds the sweep; the seed is echoed.
    let resources = [
        SharedResource::Buf,
        SharedResource::Ctx,
        SharedResource::Pd,
        SharedResource::Mr,
        SharedResource::Cq,
        SharedResource::Qp,
    ];
    check("trace-seq-vs-partitioned", fuzz_seed(0x7_1ACE), 14, |rng, _| {
        let res = *rng.choose(&resources);
        let nthreads = [2u32, 4, 8, 16][rng.below(4) as usize];
        let ways_opts: Vec<u32> =
            [1u32, 2, 4, 8, 16].iter().copied().filter(|w| nthreads % w == 0).collect();
        let ways = *rng.choose(&ways_opts);
        let features = Features {
            postlist: [1u32, 4, 32][rng.below(3) as usize],
            unsignaled: [1u32, 16, 64][rng.below(3) as usize],
            inlining: rng.below(2) == 0,
            blueflame: rng.below(2) == 0,
        };
        let (fabric, eps) =
            EndpointPolicy::sharing(res, ways).build_fresh(nthreads).map_err(|e| e.to_string())?;
        let cfg = MsgRateConfig {
            msgs_per_thread: 128 + rng.below(384),
            features,
            ..Default::default()
        };
        let what = format!("{res:?} {ways}-way x{nthreads}, {features:?}");
        let traced_run = |cfg: MsgRateConfig| {
            let mut r = Runner::new(&fabric, &eps, cfg);
            r.set_tracing(true);
            r
        };
        let mut seq = traced_run(cfg).run();
        let reference = chrome_of(&mut seq, &what);
        let mut general = traced_run(MsgRateConfig { force_general_path: true, ..cfg }).run();
        if chrome_of(&mut general, &what) != reference {
            return Err(format!("{what}: general-path trace stream drifted"));
        }
        let workers = [1usize, 2, 4][rng.below(3) as usize];
        let (mut part, _) = traced_run(cfg).run_partitioned_with(workers);
        if chrome_of(&mut part, &what) != reference {
            return Err(format!("{what}: partitioned trace stream drifted at w={workers}"));
        }
        Ok(())
    });
}

#[test]
fn traced_workload_cell_reproduces_and_snapshot_carries_named_series() {
    // The workload driver's traced entry point is a pure function of its
    // inputs (two runs, same bytes), and the metrics snapshot carries
    // the satellite-6 named series: per-class lock contention, the
    // per-CQ high-water series, and the per-slot VCI occupancy.
    let s = Scenario::Alltoall;
    let w = s.instantiate(true);
    let n = w.shape().threads_per_rank;
    let pool = (n / 3).max(1);
    let run = || {
        run_cell_traced(&*w, &EndpointPolicy::scalable(), pool, MapStrategy::adaptive(), "workload:alltoall")
            .expect("workload cell")
    };
    let (c1, t1, v1) = run();
    let (c2, t2, v2) = run();
    assert_eq!(render_chrome(&t1), render_chrome(&t2), "traced workload cell not reproducible");
    let snap = |c: &scalable_ep::workload::drive::WorkloadCell,
                t: &Trace,
                v: &scalable_ep::trace::VciSnapshot| {
        snapshot(&SnapshotInput {
            label: &t.label,
            result: &c.result,
            parts: None,
            vci: Some(v),
            trace: Some(t),
        })
        .render(1)
    };
    let rendered = snap(&c1, &t1, &v1);
    assert_eq!(rendered, snap(&c2, &t2, &v2), "snapshot bytes not reproducible");
    let parsed = Json::parse(&rendered).expect("snapshot renders parseable JSON");
    for series in [
        "lock_contended_qp",
        "lock_contended_cq",
        "lock_contended_uuar",
        "cq_high_water",
        "vci_slot_loads",
        "vci_migrations",
        "vci_rehomed",
        "trace_events",
    ] {
        assert!(parsed.get(series).is_some(), "snapshot missing series '{series}': {rendered}");
    }
    let loads = parsed.get("vci_slot_loads").and_then(Json::as_arr).unwrap();
    assert_eq!(loads.len(), pool as usize, "one occupancy entry per pool slot");
}
