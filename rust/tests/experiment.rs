//! End-to-end coverage for the experiment harness: config parsing,
//! report determinism, the `run_fleet` equivalence contract, compare
//! exit semantics, and the SLO capacity search (satellites 4 and 5 of
//! the harness PR).

use scalable_ep::coordinator::run_fleet;
use scalable_ep::experiment::{
    capacity_search, compare, default_tols, run_experiment, ExperimentConfig, Report, SloMetric,
    SloProbeSpec, SloSpec,
};

/// The committed fleet-quick config, inlined so the test is hermetic
/// (integration tests run from the crate root; the committed copy in
/// `experiments/` is exercised by the CI smoke leg).
const FLEET_QUICK: &str = r#"{
  "name": "fleet-quick",
  "kind": "fleet",
  "ranks": 4,
  "streams": 8,
  "pool": 4,
  "map": "hash",
  "msgs": 128,
  "seed": 7
}"#;

const POOL_SWEEP: &str = r#"{
  "name": "mini-frontier",
  "kind": "pool-sweep",
  "threads": 4,
  "pools": [4, 2],
  "msgs": 512
}"#;

#[test]
fn config_round_trips_through_its_echo() {
    let cfg = ExperimentConfig::parse(FLEET_QUICK).unwrap();
    let echoed = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(cfg, echoed, "to_json -> from_json is the identity");
}

#[test]
fn config_errors_name_the_key_and_valid_values() {
    let e = ExperimentConfig::parse(r#"{"name": "x", "kind": "vibes"}"#).unwrap_err();
    assert!(e.contains("fleet"), "kind error lists valid kinds: {e}");
    let e = ExperimentConfig::parse(r#"{"name": "x", "kind": "fleet", "banana": 1}"#).unwrap_err();
    assert!(e.contains("banana") && e.contains("valid"), "{e}");
    let e = ExperimentConfig::parse(r#"{"name": "x", "kind": "figure"}"#).unwrap_err();
    assert!(e.contains("figure") && e.contains("fig2"), "lists figure names: {e}");
}

#[test]
fn fleet_experiment_reproduces_run_fleet_bit_exactly() {
    let cfg = ExperimentConfig::parse(FLEET_QUICK).unwrap();
    let rep = run_experiment(&cfg).unwrap();
    let cell = run_fleet(&cfg.fleet_config(cfg.seed));
    let row = &rep.rows[0];
    assert_eq!(row.label, cell.model);
    // f64 equality on purpose: the experiment path must be the *same*
    // computation as `scep fleet`, not an approximation of it.
    assert_eq!(row.get("messages").unwrap(), cell.messages as f64);
    assert_eq!(row.get("rate_mmsgs").unwrap(), cell.rate_mmsgs);
    assert_eq!(row.get("p50_ns").unwrap(), cell.p50_ns);
    assert_eq!(row.get("p99_ns").unwrap(), cell.p99_ns);
    assert_eq!(row.get("p999_ns").unwrap(), cell.p999_ns);
    assert_eq!(row.get("rehomed").unwrap(), cell.rehomed as f64);
    assert_eq!(row.get("sched_steps").unwrap(), cell.sched_steps as f64);
}

#[test]
fn report_json_is_byte_identical_across_runs_and_round_trips() {
    let cfg = ExperimentConfig::parse(FLEET_QUICK).unwrap();
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a, b, "fixed seed: identical reports");
    let ta = a.to_json_text();
    assert_eq!(ta, b.to_json_text(), "... and byte-identical JSON");
    let parsed = Report::parse(&ta).unwrap();
    assert_eq!(parsed, a, "serde round trip");
    assert_eq!(parsed.to_json_text(), ta, "canonical: reserialization is a fixed point");
}

#[test]
fn seed_moves_the_fleet_rows() {
    let cfg = ExperimentConfig::parse(FLEET_QUICK).unwrap();
    let mut other = cfg.clone();
    other.seed += 1;
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&other).unwrap();
    assert_ne!(
        a.rows[0].get("p999_ns"),
        b.rows[0].get("p999_ns"),
        "a different seed draws different arrivals"
    );
}

#[test]
fn pool_sweep_reports_the_dedicated_baseline_and_every_cell() {
    let cfg = ExperimentConfig::parse(POOL_SWEEP).unwrap();
    let rep = run_experiment(&cfg).unwrap();
    assert_eq!(rep.rows[0].label, "dedicated/4");
    // 1 baseline + 2 pools x 3 strategies.
    assert_eq!(rep.rows.len(), 7);
    for row in &rep.rows {
        assert!(row.get("rate_mmsgs").unwrap() > 0.0, "{}: rate present", row.label);
        assert!(row.get("memory_mib").unwrap() > 0.0, "{}: usage present", row.label);
    }
}

#[test]
fn compare_breaches_on_an_injected_rate_delta() {
    let cfg = ExperimentConfig::parse(FLEET_QUICK).unwrap();
    let a = run_experiment(&cfg).unwrap();
    let mut b = a.clone();
    // Inject a 15% simulated-rate regression into every row.
    for row in &mut b.rows {
        for (name, v) in &mut row.metrics {
            if name == "rate_mmsgs" {
                *v *= 0.85;
            }
        }
    }
    let (tol, wtol) = default_tols(&a);
    assert_eq!(tol, 10.0, "the config default rides in the report");
    assert!(compare(&a, &a.clone(), tol, wtol).ok(), "self-compare passes");
    let out = compare(&a, &b, tol, wtol);
    assert!(!out.ok(), "15% delta vs 10% band must breach");
    assert!(out.diffs.iter().any(|d| d.metric == "rate_mmsgs" && d.breach));
}

#[test]
fn slo_search_in_an_experiment_holds_its_bound() {
    let text = r#"{
      "name": "slo-mini",
      "kind": "fleet",
      "ranks": 1,
      "streams": 4,
      "pool": 2,
      "map": "rr",
      "msgs": 256,
      "traffic": "poisson:800",
      "seed": 5,
      "slo": { "metric": "p999", "bound_ns": 40000, "probes": 3, "lo_mult": 0.5, "hi_mult": 2.0 }
    }"#;
    let cfg = ExperimentConfig::parse(text).unwrap();
    let rep = run_experiment(&cfg).unwrap();
    let slo = cfg.slo.unwrap();
    if let Some(found) = rep.rows.iter().find(|r| r.label == "slo:found") {
        assert!(found.get("p999_ns").unwrap() <= slo.bound_ns, "found rate holds the bound");
        assert_eq!(found.get("holds"), Some(1.0));
        if let Some(breach) = rep.rows.iter().find(|r| r.label == "slo:breach") {
            assert!(breach.get("p999_ns").unwrap() > slo.bound_ns);
            assert!(
                found.get("mult").unwrap() < breach.get("mult").unwrap(),
                "the bracket is ordered: capacity below the first breaching rate"
            );
        }
    } else {
        // Infeasible bound: the report must carry the breach instead.
        let breach = rep.rows.iter().find(|r| r.label == "slo:breach").unwrap();
        assert!(breach.get("p999_ns").unwrap() > slo.bound_ns);
    }
    // The whole report — search trajectory included — is deterministic.
    assert_eq!(rep.to_json_text(), run_experiment(&cfg).unwrap().to_json_text());
}

#[test]
fn slo_monotonicity_guard_across_the_bracket() {
    let spec = SloProbeSpec {
        policy: scalable_ep::EndpointPolicy::scalable(),
        pool: 2,
        map: scalable_ep::vci::MapStrategy::RoundRobin,
        streams: 4,
        msgs: 256,
        traffic: scalable_ep::bench::TrafficModel::Poisson { mean_gap_ns: 800.0 },
        seed: 5,
    };
    let slo =
        SloSpec { metric: SloMetric::P999, bound_ns: 30000.0, probes: 4, lo_mult: 0.5, hi_mult: 2.0 };
    let out = capacity_search(&spec, &slo).unwrap();
    if let (Some(found), Some(breach)) = (out.found, out.breach) {
        assert!(found.holds && found.metric_ns <= slo.bound_ns);
        assert!(!breach.holds && breach.metric_ns > slo.bound_ns);
        assert!(found.mult < breach.mult);
        // No probe between found and breach contradicts the bracket:
        // anything that held is <= found.mult, anything that breached
        // is >= breach.mult.
        for p in &out.probes {
            if p.holds {
                assert!(p.mult <= found.mult, "held probe above the found capacity");
            } else {
                assert!(p.mult >= breach.mult, "breaching probe below the bracket");
            }
        }
    }
    assert_eq!(out, capacity_search(&spec, &slo).unwrap(), "trajectory determinism");
}
