//! Integration: the AOT artifacts load, compile and execute through the
//! PJRT CPU client, and the numerics match host-side oracles.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo
//! test` stays runnable from a clean checkout).

use scalable_ep::runtime::{ArtifactRuntime, DGEMM_TILE, STENCIL_TILE};

fn runtime() -> Option<ArtifactRuntime> {
    let dir = ArtifactRuntime::default_dir();
    if !dir.join("dgemm_tile.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactRuntime::new(dir).expect("PJRT CPU client"))
}

fn xorshift_f32(state: &mut u64) -> f32 {
    let mut x = *state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    *state = x;
    ((x >> 40) as f32) / (1u64 << 24) as f32 - 0.5
}

#[test]
fn dgemm_tile_matches_host_oracle() {
    let Some(mut rt) = runtime() else { return };
    let n = DGEMM_TILE;
    let mut s = 0xDEADBEEFu64;
    let a: Vec<f32> = (0..n * n).map(|_| xorshift_f32(&mut s)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| xorshift_f32(&mut s)).collect();
    let c: Vec<f32> = (0..n * n).map(|_| xorshift_f32(&mut s)).collect();
    let got = rt.dgemm_tile(&a, &b, &c).expect("execute");
    // Host oracle in f64.
    for i in 0..n {
        for j in 0..n {
            let mut acc = c[i * n + j] as f64;
            for k in 0..n {
                acc += a[i * n + k] as f64 * b[k * n + j] as f64;
            }
            let err = (acc - got[i * n + j] as f64).abs();
            assert!(err < 1e-3, "({i},{j}): {} vs {acc} (err {err})", got[i * n + j]);
        }
    }
}

#[test]
fn dgemm_identity_b() {
    let Some(mut rt) = runtime() else { return };
    let n = DGEMM_TILE;
    let mut s = 7u64;
    let a: Vec<f32> = (0..n * n).map(|_| xorshift_f32(&mut s)).collect();
    let mut b = vec![0f32; n * n];
    for i in 0..n {
        b[i * n + i] = 1.0;
    }
    let c = vec![0f32; n * n];
    let got = rt.dgemm_tile(&a, &b, &c).expect("execute");
    for i in 0..n * n {
        assert!((got[i] - a[i]).abs() < 1e-6);
    }
}

#[test]
fn stencil_tile_matches_host_oracle() {
    let Some(mut rt) = runtime() else { return };
    let h = STENCIL_TILE + 2;
    let mut s = 0xFACEu64;
    let x: Vec<f32> = (0..h * h).map(|_| xorshift_f32(&mut s)).collect();
    let got = rt.stencil_tile(&x).expect("execute");
    for r in 0..STENCIL_TILE {
        for c in 0..STENCIL_TILE {
            let (i, j) = (r + 1, c + 1);
            let want = 0.25
                * (x[(i - 1) * h + j] + x[(i + 1) * h + j] + x[i * h + j - 1] + x[i * h + j + 1]);
            let err = (want - got[r * STENCIL_TILE + c]).abs();
            assert!(err < 1e-5, "({r},{c}): err {err}");
        }
    }
}

#[test]
fn stencil_constant_fixed_point() {
    let Some(mut rt) = runtime() else { return };
    let h = STENCIL_TILE + 2;
    let x = vec![2.5f32; h * h];
    let got = rt.stencil_tile(&x).expect("execute");
    assert!(got.iter().all(|&v| (v - 2.5).abs() < 1e-6));
}

#[test]
fn bad_tile_sizes_rejected() {
    let Some(mut rt) = runtime() else { return };
    assert!(rt.dgemm_tile(&[0.0; 4], &[0.0; 4], &[0.0; 4]).is_err());
    assert!(rt.stencil_tile(&[0.0; 9]).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let mut rt = ArtifactRuntime::new("/nonexistent-artifacts").expect("client");
    let n = DGEMM_TILE * DGEMM_TILE;
    let err = rt.dgemm_tile(&vec![0.0; n], &vec![0.0; n], &vec![0.0; n]).unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err}");
}
