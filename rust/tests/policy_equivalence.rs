//! Preset-equivalence suite: `EndpointPolicy::preset(c)` and
//! `EndpointPolicy::sharing(r, x)` must reproduce the historical
//! `EndpointBuilder` / `SharingSpec` topologies *byte-for-byte* — same
//! object arenas (ids, order, addresses), same UAR page maps, same
//! accounting. The `legacy` module below is a frozen, verbatim port of
//! the pre-policy construction code; comparing full `Debug` renderings of
//! the fabrics pins every field of every arena, which is what keeps the
//! golden fig2/fig9/fig11 fixtures (tests/figures_shape.rs) unchanged
//! across the API redesign.
//!
//! Also home of the §VII scalable-endpoint acceptance test: the
//! `EndpointPolicy::scalable` preset must match Dynamic's message rate
//! under the §IV defaults while allocating at most half its uUARs.

use scalable_ep::bench::{MsgRateConfig, Runner, SharedResource};
use scalable_ep::endpoints::{Category, EndpointPolicy, ResourceUsage, ThreadEndpoint};
use scalable_ep::testing::assert_rel_close;
use scalable_ep::verbs::Fabric;

/// Frozen pre-policy builders (the deleted `EndpointBuilder::build` and
/// `SharingSpec::build` bodies, verbatim up to free-function syntax). Do
/// NOT "fix" or modernize this code: it is the reference the policy
/// presets are pinned against.
mod legacy {
    use scalable_ep::bench::SharedResource;
    use scalable_ep::endpoints::{Category, ThreadEndpoint};
    use scalable_ep::mlx5::Mlx5Env;
    use scalable_ep::verbs::error::Result;
    use scalable_ep::verbs::{BufId, Fabric, QpCaps, TdInitAttr};

    /// The old `EndpointBuilder::build` at its defaults (cq_depth 2,
    /// cache-aligned 2 B buffers, no shared BUF).
    pub fn build_category(
        category: Category,
        nthreads: u32,
        fabric: &mut Fabric,
    ) -> Result<Vec<ThreadEndpoint>> {
        use Category::*;
        let n = nthreads;
        let qp_caps = QpCaps::default();
        let cq_depth = 2u32;
        let msg_size = 2u32;
        let mut threads: Vec<ThreadEndpoint> = Vec::with_capacity(n as usize);

        let base = 0x10_0000 * (fabric.bufs.len() as u64 + 1);
        let buf_for = |fabric: &mut Fabric, i: u32| -> BufId {
            fabric.declare_buf(base + i as u64 * 64, msg_size as u64)
        };

        match category {
            MpiEverywhere => {
                for i in 0..n {
                    let ctx = fabric.open_ctx(Mlx5Env::default())?;
                    let pd = fabric.alloc_pd(ctx)?;
                    let cq = fabric.create_cq(ctx, cq_depth)?;
                    let qp = fabric.create_qp(pd, cq, qp_caps, None)?;
                    let buf = buf_for(fabric, i);
                    let mr = fabric.reg_mr(pd, fabric.buf(buf).addr, msg_size as u64)?;
                    threads.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
            TwoXDynamic | Dynamic | SharedDynamic => {
                let ctx = fabric.open_ctx(Mlx5Env::default())?;
                let pd = fabric.alloc_pd(ctx)?;
                let attr = if category == SharedDynamic {
                    TdInitAttr::paired()
                } else {
                    TdInitAttr::independent()
                };
                let qps_to_make = if category == TwoXDynamic { 2 * n } else { n };
                let mut all_qps = Vec::new();
                for _ in 0..qps_to_make {
                    let td = fabric.alloc_td(ctx, attr)?;
                    let cq = fabric.create_cq(ctx, cq_depth)?;
                    let qp = fabric.create_qp(pd, cq, qp_caps, Some(td))?;
                    all_qps.push((qp, cq));
                }
                for i in 0..n {
                    let k = if category == TwoXDynamic { 2 * i } else { i } as usize;
                    let (qp, cq) = all_qps[k];
                    let buf = buf_for(fabric, i);
                    let mr = fabric.reg_mr(pd, fabric.buf(buf).addr, msg_size as u64)?;
                    threads.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
            Static => {
                let ctx = fabric.open_ctx(Mlx5Env::default())?;
                let pd = fabric.alloc_pd(ctx)?;
                for i in 0..n {
                    let cq = fabric.create_cq(ctx, cq_depth)?;
                    let qp = fabric.create_qp(pd, cq, qp_caps, None)?;
                    let buf = buf_for(fabric, i);
                    let mr = fabric.reg_mr(pd, fabric.buf(buf).addr, msg_size as u64)?;
                    threads.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
            MpiThreads => {
                let ctx = fabric.open_ctx(Mlx5Env::default())?;
                let pd = fabric.alloc_pd(ctx)?;
                let cq = fabric.create_cq(ctx, cq_depth.max(n * 2))?;
                let qp = fabric.create_qp(pd, cq, qp_caps, None)?;
                for i in 0..n {
                    let buf = buf_for(fabric, i);
                    let mr = fabric.reg_mr(pd, fabric.buf(buf).addr, msg_size as u64)?;
                    threads.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
        }
        Ok(threads)
    }

    /// The old `SharingSpec::build` at its defaults (cq_depth 64,
    /// cache-aligned 2 B buffers).
    pub fn build_sharing(
        resource: SharedResource,
        ways: u32,
        nthreads: u32,
    ) -> Result<(Fabric, Vec<ThreadEndpoint>)> {
        assert!(ways >= 1 && nthreads % ways == 0, "x must divide the thread count");
        let mut f = Fabric::connectx4();
        let n = nthreads;
        let x = ways;
        let groups = n / x;
        let qp_caps = QpCaps::default();
        let cq_depth = 64u32;
        let msg_size = 2u32;
        let mut eps: Vec<ThreadEndpoint> = Vec::with_capacity(n as usize);

        let buf_base = 0x40_0000u64;
        let buf_addr = |i: u32| buf_base + i as u64 * 64;

        match resource {
            SharedResource::Buf => {
                for i in 0..n {
                    let ctx = f.open_ctx(Mlx5Env::default())?;
                    let pd = f.alloc_pd(ctx)?;
                    let cq = f.create_cq(ctx, cq_depth)?;
                    let td = f.alloc_td(ctx, TdInitAttr::independent())?;
                    let qp = f.create_qp(pd, cq, qp_caps, Some(td))?;
                    let shared_addr = buf_addr((i / x) * x);
                    let buf = f.declare_buf(shared_addr, msg_size as u64);
                    let mr = f.reg_mr(pd, shared_addr, msg_size as u64)?;
                    eps.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
            SharedResource::Ctx | SharedResource::CtxTwoXQps | SharedResource::CtxSharing2 => {
                for g in 0..groups {
                    let ctx = f.open_ctx(Mlx5Env::default())?;
                    let pd = f.alloc_pd(ctx)?;
                    let (attr, stride) = match resource {
                        SharedResource::CtxTwoXQps => (TdInitAttr::independent(), 2),
                        SharedResource::CtxSharing2 => (TdInitAttr::paired(), 1),
                        _ => (TdInitAttr::independent(), 1),
                    };
                    let mut group_eps = Vec::new();
                    for _ in 0..(x * stride) {
                        let td = f.alloc_td(ctx, attr)?;
                        let cq = f.create_cq(ctx, cq_depth)?;
                        let qp = f.create_qp(pd, cq, qp_caps, Some(td))?;
                        group_eps.push((qp, cq));
                    }
                    for k in 0..x {
                        let i = g * x + k;
                        let (qp, cq) = group_eps[(k * stride) as usize];
                        let addr = buf_addr(i);
                        let buf = f.declare_buf(addr, msg_size as u64);
                        let mr = f.reg_mr(pd, addr, msg_size as u64)?;
                        eps.push(ThreadEndpoint { qp, cq, buf, mr });
                    }
                }
            }
            SharedResource::Pd | SharedResource::Mr => {
                let ctx = f.open_ctx(Mlx5Env::default())?;
                let shared_pd = resource == SharedResource::Pd;
                let pds: Vec<_> = if shared_pd {
                    (0..groups).map(|_| f.alloc_pd(ctx)).collect::<Result<_>>()?
                } else {
                    vec![f.alloc_pd(ctx)?]
                };
                let one_pd = pds[0];
                let mut group_mr = Vec::new();
                if resource == SharedResource::Mr {
                    for g in 0..groups {
                        let base = buf_addr(g * x);
                        group_mr.push(f.reg_mr(one_pd, base, x as u64 * 64)?);
                    }
                }
                for i in 0..n {
                    let g = i / x;
                    let pd = if shared_pd { pds[g as usize] } else { one_pd };
                    let td = f.alloc_td(ctx, TdInitAttr::independent())?;
                    let cq = f.create_cq(ctx, cq_depth)?;
                    let qp = f.create_qp(pd, cq, qp_caps, Some(td))?;
                    let addr = buf_addr(i);
                    let buf = f.declare_buf(addr, msg_size as u64);
                    let mr = if shared_pd {
                        f.reg_mr(pd, addr, msg_size as u64)?
                    } else {
                        group_mr[g as usize]
                    };
                    eps.push(ThreadEndpoint { qp, cq, buf, mr });
                }
            }
            SharedResource::Cq => {
                let ctx = f.open_ctx(Mlx5Env::default())?;
                let pd = f.alloc_pd(ctx)?;
                for g in 0..groups {
                    let cq = f.create_cq(ctx, cq_depth.max(2 * x))?;
                    for k in 0..x {
                        let i = g * x + k;
                        let td = f.alloc_td(ctx, TdInitAttr::independent())?;
                        let qp = f.create_qp(pd, cq, qp_caps, Some(td))?;
                        let addr = buf_addr(i);
                        let buf = f.declare_buf(addr, msg_size as u64);
                        let mr = f.reg_mr(pd, addr, msg_size as u64)?;
                        eps.push(ThreadEndpoint { qp, cq, buf, mr });
                    }
                }
            }
            SharedResource::Qp => {
                let ctx = f.open_ctx(Mlx5Env::default())?;
                let pd = f.alloc_pd(ctx)?;
                for g in 0..groups {
                    let cq = f.create_cq(ctx, cq_depth.max(2 * x))?;
                    let qp = f.create_qp(pd, cq, qp_caps, None)?;
                    for k in 0..x {
                        let i = g * x + k;
                        let addr = buf_addr(i);
                        let buf = f.declare_buf(addr, msg_size as u64);
                        let mr = f.reg_mr(pd, addr, msg_size as u64)?;
                        eps.push(ThreadEndpoint { qp, cq, buf, mr });
                    }
                }
            }
        }
        Ok((f, eps))
    }
}

/// Byte-level topology comparison: full `Debug` of the fabric arenas
/// (every id, address, uUAR map, lock flag, depth) plus the per-thread
/// endpoint bindings.
fn assert_same_topology(
    what: &str,
    got_fabric: &Fabric,
    got_eps: &[ThreadEndpoint],
    want_fabric: &Fabric,
    want_eps: &[ThreadEndpoint],
) {
    assert_eq!(got_eps, want_eps, "{what}: thread endpoint bindings diverged");
    let (gs, ws) = (format!("{got_fabric:?}"), format!("{want_fabric:?}"));
    if gs != ws {
        // Locate the first diverging fragment for a readable failure.
        let at = gs.bytes().zip(ws.bytes()).position(|(a, b)| a != b).unwrap_or(0);
        let lo = at.saturating_sub(120);
        panic!(
            "{what}: fabric arenas diverged near byte {at}:\n policy: ...{}...\n legacy: ...{}...",
            &gs[lo..(at + 120).min(gs.len())],
            &ws[lo..(at + 120).min(ws.len())],
        );
    }
    assert_eq!(
        ResourceUsage::of_fabric(got_fabric),
        ResourceUsage::of_fabric(want_fabric),
        "{what}: accounting diverged"
    );
}

#[test]
fn category_presets_reproduce_legacy_builder_byte_for_byte() {
    for cat in Category::ALL {
        for n in [1u32, 2, 8, 16] {
            let mut legacy_fabric = Fabric::connectx4();
            let legacy_eps = legacy::build_category(cat, n, &mut legacy_fabric).unwrap();
            let mut policy_fabric = Fabric::connectx4();
            let set = EndpointPolicy::preset(cat).build(&mut policy_fabric, n).unwrap();
            assert_same_topology(
                &format!("{cat} x{n}"),
                &policy_fabric,
                &set.threads,
                &legacy_fabric,
                &legacy_eps,
            );
        }
    }
}

#[test]
fn category_presets_reproduce_legacy_builder_on_dirty_fabric() {
    // The auto buffer base depends on pre-existing buffers; both paths
    // must agree on a fabric that already carries state.
    for cat in [Category::Dynamic, Category::MpiThreads] {
        let mut legacy_fabric = Fabric::connectx4();
        legacy_fabric.declare_buf(0x8000, 64);
        let first = legacy::build_category(Category::Static, 4, &mut legacy_fabric).unwrap();
        let legacy_eps = legacy::build_category(cat, 8, &mut legacy_fabric).unwrap();
        let mut policy_fabric = Fabric::connectx4();
        policy_fabric.declare_buf(0x8000, 64);
        let pfirst = EndpointPolicy::preset(Category::Static).build(&mut policy_fabric, 4).unwrap();
        let set = EndpointPolicy::preset(cat).build(&mut policy_fabric, 8).unwrap();
        assert_eq!(pfirst.threads, first, "{cat}: first build diverged");
        assert_same_topology(
            &format!("{cat} after prior build"),
            &policy_fabric,
            &set.threads,
            &legacy_fabric,
            &legacy_eps,
        );
    }
}

#[test]
fn sharing_presets_reproduce_legacy_sweeps_byte_for_byte() {
    for res in SharedResource::ALL {
        for ways in [1u32, 2, 4, 8, 16] {
            let (legacy_fabric, legacy_eps) = legacy::build_sharing(res, ways, 16).unwrap();
            let (policy_fabric, policy_eps) =
                EndpointPolicy::sharing(res, ways).build_fresh(16).unwrap();
            assert_same_topology(
                &format!("{res:?} {ways}-way x16"),
                &policy_fabric,
                &policy_eps,
                &legacy_fabric,
                &legacy_eps,
            );
        }
    }
}

#[test]
fn sharing_presets_reproduce_legacy_sweeps_at_other_thread_counts() {
    for res in SharedResource::ALL {
        for (ways, n) in [(1u32, 4u32), (2, 8), (4, 8), (8, 32)] {
            let (legacy_fabric, legacy_eps) = legacy::build_sharing(res, ways, n).unwrap();
            let (policy_fabric, policy_eps) =
                EndpointPolicy::sharing(res, ways).build_fresh(n).unwrap();
            assert_same_topology(
                &format!("{res:?} {ways}-way x{n}"),
                &policy_fabric,
                &policy_eps,
                &legacy_fabric,
                &legacy_eps,
            );
        }
    }
}

#[test]
fn scalable_endpoint_matches_dynamic_rate_at_half_the_uuars() {
    // Acceptance: under the §IV defaults (Postlist 32, Unsignaled 64) the
    // §VII scalable preset must match Dynamic's 16-thread message rate
    // within the model while allocating at most half its uUARs.
    let mut fd = Fabric::connectx4();
    let dynamic = EndpointPolicy::preset(Category::Dynamic).build(&mut fd, 16).unwrap();
    let mut fs = Fabric::connectx4();
    let scalable = EndpointPolicy::scalable().build(&mut fs, 16).unwrap();
    let cfg = MsgRateConfig { msgs_per_thread: 16 * 1024, ..Default::default() };
    let rd = Runner::new(&fd, &dynamic.threads, cfg).run();
    let rs = Runner::new(&fs, &scalable.threads, cfg).run();
    assert_rel_close(
        rs.mmsgs_per_sec,
        rd.mmsgs_per_sec,
        0.02,
        "scalable vs Dynamic 16-thread rate",
    );
    let ud = ResourceUsage::of_set(&fd, &dynamic);
    let us = ResourceUsage::of_set(&fs, &scalable);
    assert_eq!(ud.uuars_allocated, 48, "Dynamic baseline");
    assert_eq!(us.uuars_allocated, 18, "1 trimmed static page + 8 paired dynamic pages");
    assert!(
        2 * us.uuars_allocated <= ud.uuars_allocated,
        "scalable must use at most half of Dynamic's uUARs ({} vs {})",
        us.uuars_allocated,
        ud.uuars_allocated
    );
    // Memory shrinks with the trimmed CTX provisioning too.
    assert!(us.memory_bytes <= ud.memory_bytes);
}
