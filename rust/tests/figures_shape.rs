//! Shape-level assertions over the paper's figures: who wins, by roughly
//! what factor, where crossovers fall. These are the reproduction
//! acceptance tests (EXPERIMENTS.md cites them).

use scalable_ep::bench::{Features, MsgRateConfig, Runner, SharedResource};
use scalable_ep::coordinator::JobSpec;
use scalable_ep::apps::stencil::DEFAULT_HALO_BYTES;
use scalable_ep::apps::{GlobalArray, StencilBench};
use scalable_ep::endpoints::{BufLayout, Category, EndpointPolicy, ResourceUsage};
use scalable_ep::verbs::Fabric;
use scalable_ep::workload::Scenario;

const MSGS: u64 = 16 * 1024;

fn run_sharing(res: SharedResource, ways: u32, features: Features) -> f64 {
    let (fabric, eps) = EndpointPolicy::sharing(res, ways).build_fresh(16).unwrap();
    let cfg = MsgRateConfig { msgs_per_thread: MSGS, features, ..Default::default() };
    Runner::new(&fabric, &eps, cfg).run().mmsgs_per_sec
}

fn run_category(cat: Category, n: u32, features: Features) -> f64 {
    let mut f = Fabric::connectx4();
    let set = EndpointPolicy::preset(cat).build(&mut f, n).unwrap();
    let cfg = MsgRateConfig { msgs_per_thread: MSGS, features, ..Default::default() };
    Runner::new(&f, &set.threads, cfg).run().mmsgs_per_sec
}

// ------------------------------------------- Golden snapshots (engine net)

/// Byte-identity pin on the `--quick` table output of fig2/fig9/fig11
/// plus the VCI pool sweep, the §VII application figures (fig12/fig14 —
/// pinned across the workload-trait refactor, tests/workload.rs holds
/// the matching legacy differential) and the pluggable workload sweep:
/// the DES engine is bit-deterministic, so ANY engine change that
/// perturbs results — a fast path that is not exact, a cost-model edit,
/// a scheduler reorder, a stream-placement change — fails this test
/// loudly instead of silently shifting the reproduction's numbers.
///
/// Fixtures live in `tests/fixtures/<fig>_quick.golden.txt`. A missing
/// fixture (or `SCEP_BLESS=1`) is written from the current engine and
/// the test passes with a loud note (a `::warning::` annotation on CI,
/// never silently): the build container that grows this repo has no
/// Rust toolchain, so first-generation happens on CI, which uploads
/// `tests/fixtures/` as an artifact for check-in. On mismatch the fresh
/// bytes are written next to the fixture as `*.new` (the CI artifact
/// then carries the diff) and the test fails.
///
/// `SCEP_REQUIRE_GOLDEN=1` arms the pinning: a missing fixture then
/// *fails* instead of self-blessing. CI's golden-diff leg sets it as
/// soon as any fixture is committed, so a partial check-in or a deleted
/// fixture can never silently re-bless itself.
#[test]
fn golden_fig_tables_are_byte_stable() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let require = std::env::var("SCEP_REQUIRE_GOLDEN").is_ok();
    for name in ["fig2", "fig9", "fig11", "pool", "fig12", "fig14", "workloads"] {
        // (Run-to-run determinism itself is pinned by `deterministic` in
        // bench::msgrate and the worker-pool invariants; one render per
        // figure keeps this test affordable in debug CI.)
        let bytes = scalable_ep::figures::render_bytes(name, true).expect("known figure");
        let path = dir.join(format!("{name}_quick.golden.txt"));
        let bless = std::env::var("SCEP_BLESS").is_ok();
        if !path.exists() && require && !bless {
            panic!(
                "{name}: SCEP_REQUIRE_GOLDEN is set but {} is not committed — \
                 download the golden-fixtures CI artifact (or run with SCEP_BLESS=1) \
                 and commit the fixture",
                path.display()
            );
        }
        if bless || !path.exists() {
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(&path, &bytes).unwrap();
            // The `::warning::` form surfaces as a GitHub Actions
            // annotation, so a self-bless is visible on the run summary,
            // not buried in the log.
            eprintln!(
                "::warning::[golden] blessed {} ({} bytes) — commit it so the \
                 byte-pinning arms",
                path.display(),
                bytes.len()
            );
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        if want != bytes {
            let new_path = path.with_extension("txt.new");
            std::fs::write(&new_path, &bytes).unwrap();
            let first_diff = want
                .lines()
                .zip(bytes.lines())
                .position(|(a, b)| a != b)
                .unwrap_or_else(|| want.lines().count().min(bytes.lines().count()));
            panic!(
                "{name}: --quick table bytes diverged from {} (first differing line {}); \
                 fresh bytes written to {} — if the change is intentional, re-bless with \
                 SCEP_BLESS=1 and commit",
                path.display(),
                first_diff + 1,
                new_path.display()
            );
        }
    }
}

/// The policy grid (message-size x sharing-level x threads) must cover
/// its full 5 x 5 x 2 cell matrix — 50 CSV rows plus the header —
/// include the §VII scalable preset, and exercise the 32-thread tier
/// past the paper's 16-thread ceiling (ROADMAP item) under `--quick`.
#[test]
fn policy_grid_covers_size_by_level_by_threads_matrix() {
    let bytes = scalable_ep::figures::render_bytes("grid", true).expect("known figure");
    let csv: Vec<&str> = bytes.lines().filter(|l| l.starts_with("csv,")).collect();
    assert_eq!(csv.len(), 1 + 5 * 5 * 2, "header + 50 cells");
    assert!(bytes.contains("Scalable"), "scalable preset missing from the grid");
    assert!(bytes.contains("1024"), "largest message size missing");
    // Every policy appears at both thread tiers (threads is token 4 of
    // a data line: csv,<slug>,msg_B,policy,threads,...).
    for tier in scalable_ep::figures::GRID_THREADS {
        let want = tier.to_string();
        let rows = csv[1..]
            .iter()
            .filter(|l| l.split(',').nth(4) == Some(want.as_str()))
            .count();
        assert_eq!(rows, 5 * 5, "{tier}-thread tier incomplete");
    }
}

/// The VCI pool sweep must cover its full matrix at both stream tiers:
/// per tier, one dedicated baseline row plus {n, n/2, n/3, n/4} pool
/// sizes x {rr, hash, adaptive} strategies — and the paper's headline
/// pool = threads/3 point must be present.
#[test]
fn pool_figure_covers_size_by_strategy_matrix() {
    let bytes = scalable_ep::figures::render_bytes("pool", true).expect("known figure");
    let csv: Vec<&str> = bytes.lines().filter(|l| l.starts_with("csv,")).collect();
    let per_tier = 1 + 4 * 3;
    assert_eq!(csv.len(), 1 + 2 * per_tier, "header + 2 tiers x 13 rows");
    for strategy in ["dedicated", "rr", "hash", "adaptive:2"] {
        assert!(bytes.contains(strategy), "strategy '{strategy}' missing");
    }
    // Data line tokens: csv,<slug>,threads,policy,pool,map,...
    for tier in scalable_ep::figures::GRID_THREADS {
        let want = tier.to_string();
        let rows: Vec<&&str> =
            csv[1..].iter().filter(|l| l.split(',').nth(2) == Some(want.as_str())).collect();
        assert_eq!(rows.len(), per_tier, "{tier}-stream tier incomplete");
        // The headline point: the scalable preset at pool = threads/3.
        let third = (tier / 3).to_string();
        assert!(
            rows.iter().any(|l| {
                let mut it = l.split(',');
                it.nth(3) == Some("Scalable") && it.next() == Some(third.as_str())
            }),
            "{tier}-stream tier lacks the pool = threads/3 scalable point"
        );
    }
}

/// The pluggable workload figure must run the full policy × pool × map
/// sweep for every scenario through the shared generic driver: per
/// scenario, one dedicated baseline row plus {n, n/2, n/3, n/4} pool
/// sizes × {rr, hash, adaptive} strategies over two pooled policies —
/// and the `everywhere` table must lead with the MPI-everywhere side of
/// the head-to-head so both models sit in one table at equal core count.
#[test]
fn workloads_figure_covers_every_scenario_sweep() {
    let bytes = scalable_ep::figures::render_bytes("workloads", true).expect("known figure");
    let csv: Vec<&str> = bytes.lines().filter(|l| l.starts_with("csv,")).collect();
    // dedicated baseline + {scalable, dynamic} x 4 pool sizes x 3 maps.
    let sweep = 1 + 2 * 4 * 3;
    for s in Scenario::ALL {
        let tag = format!("csv,Workload_'{}'", s.name());
        let rows = csv.iter().filter(|l| l.starts_with(&tag)).count();
        let head_to_head = usize::from(s == Scenario::Everywhere);
        assert_eq!(rows, 1 + sweep + head_to_head, "{s}: header + sweep rows");
    }
    // The head-to-head row reports the process-per-core model (16 ranks
    // x 1 thread at the same 16-core budget as the pooled sweep below it).
    assert!(bytes.contains("everywhere 16x1"), "MPI-everywhere side missing");
    for strategy in ["dedicated", "rr", "hash", "adaptive:2"] {
        assert!(bytes.contains(strategy), "strategy '{strategy}' missing");
    }
    // The paper's headline operating point: the scalable policy at
    // pool = streams/3 (16 streams -> 5 slots) in every scenario.
    for s in Scenario::ALL {
        let tag = format!("csv,Workload_'{}'", s.name());
        assert!(
            csv.iter().any(|l| {
                let mut it = l.split(',');
                l.starts_with(&tag) && it.nth(2) == Some("scalable") && it.next() == Some("5")
            }),
            "{s}: pool = streams/3 scalable point missing"
        );
    }
}

// ------------------------------------------------------------- Fig 2(b)

#[test]
fn fig02_extremes_gap_is_several_fold_at_16_threads() {
    let every = run_category(Category::MpiEverywhere, 16, Features::all());
    let threads = run_category(Category::MpiThreads, 16, Features::all());
    let ratio = every / threads;
    // §IX: "perform up to 7x worse with multiple threads".
    assert!(ratio > 4.0 && ratio < 20.0, "ratio {ratio:.1}");
}

#[test]
fn fig02_waste_is_93_75_percent_for_mpi_everywhere() {
    let mut f = Fabric::connectx4();
    let set = EndpointPolicy::preset(Category::MpiEverywhere).build(&mut f, 16).unwrap();
    let u = ResourceUsage::of_set(&f, &set);
    assert!((u.uuar_waste_fraction() - 0.9375).abs() < 1e-9);
}

// --------------------------------------------------------------- Fig 3

#[test]
fn fig03_all_features_scale_linearly() {
    let naive = EndpointPolicy::sharing(SharedResource::Ctx, 1);
    let r1 = {
        let (f, eps) = naive.build_fresh(1).unwrap();
        Runner::new(&f, &eps, MsgRateConfig { msgs_per_thread: MSGS, ..Default::default() })
            .run()
            .mmsgs_per_sec
    };
    let r16 = {
        let (f, eps) = naive.build_fresh(16).unwrap();
        Runner::new(&f, &eps, MsgRateConfig { msgs_per_thread: MSGS, ..Default::default() })
            .run()
            .mmsgs_per_sec
    };
    assert!(r16 / r1 > 8.0, "naive endpoints should scale: {r1:.1} -> {r16:.1}");
}

#[test]
fn fig03_feature_removal_costs_throughput() {
    let all = run_sharing(SharedResource::Ctx, 1, Features::all());
    let wo_postlist = run_sharing(SharedResource::Ctx, 1, Features::all().without_postlist());
    let wo_unsignaled = run_sharing(SharedResource::Ctx, 1, Features::all().without_unsignaled());
    assert!(all > wo_postlist, "Postlist should help: {all:.1} vs {wo_postlist:.1}");
    assert!(all > wo_unsignaled * 0.99, "Unsignaled should not hurt");
}

// --------------------------------------------------------------- Fig 5

#[test]
fn fig05_buf_sharing_hurts_only_without_inlining() {
    let f = Features::all().without_inlining();
    let independent = run_sharing(SharedResource::Buf, 1, f);
    let shared = run_sharing(SharedResource::Buf, 16, f);
    assert!(
        independent / shared > 1.5,
        "16-way BUF sharing w/o inlining should serialize the TLB: {independent:.1} vs {shared:.1}"
    );
    // With inlining the CPU reads the payload: sharing is harmless.
    let with_inline = Features::all();
    let ind2 = run_sharing(SharedResource::Buf, 1, with_inline);
    let sh2 = run_sharing(SharedResource::Buf, 16, with_inline);
    assert!((ind2 / sh2 - 1.0).abs() < 0.05, "inlined BUF sharing harmless: {ind2:.1} vs {sh2:.1}");
}

// --------------------------------------------------------------- Fig 6

#[test]
fn fig06_unaligned_buffers_hurt_and_equal_pcie_reads() {
    let mk = |aligned: bool| {
        let mut policy = EndpointPolicy::sharing(SharedResource::Buf, 1);
        if !aligned {
            policy.buf = BufLayout::Packed;
        }
        let (fabric, eps) = policy.build_fresh(16).unwrap();
        let cfg = MsgRateConfig {
            msgs_per_thread: MSGS,
            features: Features::all().without_inlining(),
            ..Default::default()
        };
        Runner::new(&fabric, &eps, cfg).run()
    };
    let aligned = mk(true);
    let unaligned = mk(false);
    // Fig 6(a): slower when 16 buffers share a cacheline...
    assert!(aligned.mmsgs_per_sec / unaligned.mmsgs_per_sec > 1.5);
    // Fig 6(b): ...with the SAME total number of PCIe reads, at lower rate.
    assert_eq!(aligned.pcie.dma_reads, unaligned.pcie.dma_reads);
    assert!(aligned.pcie_read_rate > unaligned.pcie_read_rate);
}

// --------------------------------------------------------------- Fig 7

#[test]
fn fig07_ctx_sharing_is_free_with_postlist() {
    let all = Features::all();
    let one = run_sharing(SharedResource::Ctx, 1, all);
    let sixteen = run_sharing(SharedResource::Ctx, 16, all);
    assert!((one / sixteen - 1.0).abs() < 0.05, "{one:.1} vs {sixteen:.1}");
}

#[test]
fn fig07_blueflame_16way_drop_and_2xqps_fix() {
    let f = Features::all().without_postlist();
    let w8 = run_sharing(SharedResource::Ctx, 8, f);
    let w16 = run_sharing(SharedResource::Ctx, 16, f);
    let drop = w8 / w16;
    // §V-B: "a 1.15x drop ... going from 8-way to 16-way CTX sharing".
    assert!(drop > 1.08 && drop < 1.25, "drop {drop:.3}");
    // 2xQPs eliminates the drop.
    let w16_2x = run_sharing(SharedResource::CtxTwoXQps, 16, f);
    assert!((w8 / w16_2x - 1.0).abs() < 0.03, "2xQPs should recover: {w8:.1} vs {w16_2x:.1}");
    // Sharing 2 (level-2 assignment) is distinctly worse.
    let w16_s2 = run_sharing(SharedResource::CtxSharing2, 16, f);
    assert!(w16_2x / w16_s2 > 1.3, "Sharing 2 should hurt: {w16_2x:.1} vs {w16_s2:.1}");
}

// --------------------------------------------------------------- Fig 8

#[test]
fn fig08_pd_and_mr_sharing_are_performance_neutral() {
    for res in [SharedResource::Pd, SharedResource::Mr] {
        for f in [Features::all(), Features::all().without_postlist()] {
            let one = run_sharing(res, 1, f);
            let sixteen = run_sharing(res, 16, f);
            assert!(
                (one / sixteen - 1.0).abs() < 0.05,
                "{res:?}: {one:.1} vs {sixteen:.1}"
            );
        }
    }
}

// --------------------------------------------------------------- Fig 9/10

#[test]
fn fig09_cq_sharing_hurts_most_without_unsignaled() {
    let wo_unsig = Features::all().without_unsignaled();
    let one = run_sharing(SharedResource::Cq, 1, wo_unsig);
    let sixteen = run_sharing(SharedResource::Cq, 16, wo_unsig);
    assert!(one / sixteen > 2.0, "w/o Unsignaled CQ sharing: {one:.1} vs {sixteen:.1}");
    // With q=64 the drop is much smaller (benefits of batching dominate).
    let all = Features::all();
    let one_all = run_sharing(SharedResource::Cq, 1, all);
    let sixteen_all = run_sharing(SharedResource::Cq, 16, all);
    assert!(one_all / sixteen_all < one / sixteen, "q=64 should soften CQ contention");
}

#[test]
fn fig10_lower_unsignaled_values_contend_more() {
    // At 16-way CQ sharing, throughput should increase with q.
    let rate_q = |q| {
        let f = Features { postlist: 1, unsignaled: q, inlining: true, blueflame: true };
        run_sharing(SharedResource::Cq, 16, f)
    };
    let r1 = rate_q(1);
    let r16 = rate_q(16);
    let r64 = rate_q(64);
    assert!(r64 >= r16 && r16 > r1, "q sweep at 16-way: {r1:.1}, {r16:.1}, {r64:.1}");
}

// --------------------------------------------------------------- Fig 11

#[test]
fn fig11_qp_sharing_declines_monotonically() {
    let f = Features::all();
    let rates: Vec<f64> = [1u32, 2, 4, 8, 16]
        .iter()
        .map(|&w| run_sharing(SharedResource::Qp, w, f))
        .collect();
    for w in rates.windows(2) {
        assert!(w[0] > w[1] * 0.98, "QP sharing should decline: {rates:?}");
    }
    assert!(rates[0] / rates[4] > 4.0, "16-way QP sharing drop: {rates:?}");
}

#[test]
fn fig11_removing_postlist_hurts_shared_qp_more() {
    // §V-F: "Removing Postlist is more detrimental than removing
    // Unsignaled Completion" under QP sharing.
    let base = run_sharing(SharedResource::Qp, 16, Features::all());
    let wo_pl = run_sharing(SharedResource::Qp, 16, Features::all().without_postlist());
    let wo_un = run_sharing(SharedResource::Qp, 16, Features::all().without_unsignaled());
    assert!(wo_pl < wo_un, "w/o Postlist {wo_pl:.1} should be < w/o Unsignaled {wo_un:.1}");
    assert!(base > wo_pl);
}

// --------------------------------------------------------------- Fig 12

#[test]
fn fig12_categories_tradeoff_matches_paper() {
    let rate = |cat| {
        let ga = GlobalArray::new(cat, 16).unwrap();
        ga.time_comm(MSGS / 2, 2).mmsgs_per_sec
    };
    let every = rate(Category::MpiEverywhere);
    let p = |cat| rate(cat) / every;
    // Paper: 108%, 94%, 65%, 64%, 3% — allow generous bands.
    let twox = p(Category::TwoXDynamic);
    assert!(twox > 1.0 && twox < 1.2, "2xDynamic {twox:.2}");
    let dynamic = p(Category::Dynamic);
    assert!(dynamic > 0.85 && dynamic < 1.02, "Dynamic {dynamic:.2}");
    let shared = p(Category::SharedDynamic);
    assert!(shared > 0.5 && shared < 0.8, "SharedDynamic {shared:.2}");
    let statik = p(Category::Static);
    assert!(statik > 0.4 && statik < 0.8, "Static {statik:.2}");
    let threads = p(Category::MpiThreads);
    assert!(threads < 0.1, "MPI+threads {threads:.2}");
}

// --------------------------------------------------------------- Fig 14

#[test]
fn fig14_processes_only_beats_fully_hybrid_for_mpi_everywhere() {
    let rate = |spec: JobSpec| {
        let s = StencilBench::new(spec, Category::MpiEverywhere, DEFAULT_HALO_BYTES).unwrap();
        s.time_exchange(512).mmsgs_per_sec
    };
    let procs = rate(JobSpec::new(16, 1));
    let hybrid = rate(JobSpec::new(1, 16));
    // §VII: "the fully hybrid approach performs 1.4x worse".
    let ratio = procs / hybrid;
    assert!(ratio > 1.0 && ratio < 3.0, "processes-only advantage {ratio:.2}");
}

#[test]
fn fig14_16_1_td_categories_beat_locked_ones() {
    // §VII: TD categories 106%, Static 100%, MPI+threads 87% at 16.1.
    let rate = |cat| {
        let s = StencilBench::new(JobSpec::new(16, 1), cat, DEFAULT_HALO_BYTES).unwrap();
        s.time_exchange(512).mmsgs_per_sec
    };
    let every = rate(Category::MpiEverywhere);
    let dynamic = rate(Category::Dynamic) / every;
    let statik = rate(Category::Static) / every;
    let threads = rate(Category::MpiThreads) / every;
    assert!(dynamic > 1.0 && dynamic < 1.15, "Dynamic {dynamic:.3}");
    assert!((statik - 1.0).abs() < 0.06, "Static {statik:.3}");
    assert!(threads > 0.75 && threads < 0.97, "MPI+threads {threads:.3}");
}
