//! VCI subsystem acceptance pins (ISSUE 5).
//!
//! 1. `MapStrategy::Dedicated` with pool_size = threads is bit-identical
//!    to the historical per-thread-endpoint path — rates, duration,
//!    per-thread done-times, PCIe and latency accounting — across every
//!    cell of the golden fig2/fig9/fig11 tables, so the byte-pinned
//!    fixtures remain valid by construction.
//! 2. The §VII `scalable` preset over a pool a *third* the thread count
//!    matches the dedicated rate within 5 % at 16 and 32 threads while
//!    using strictly fewer hardware resources — the paper's headline
//!    rate-vs-resources point, reproduced through the stream layer.

use scalable_ep::bench::{FeatureSet, MsgRateConfig, MsgRateResult, Runner, SharedResource};
use scalable_ep::endpoints::{Category, EndpointPolicy};
use scalable_ep::vci::{run_pooled, MapStrategy};

/// Every virtual-time observable plus the engine diagnostics, bit for
/// bit.
fn assert_identical(a: &MsgRateResult, b: &MsgRateResult, what: &str) {
    assert_eq!(a.duration, b.duration, "{what}: duration");
    assert_eq!(a.thread_done, b.thread_done, "{what}: per-thread done-times");
    assert_eq!(a.messages, b.messages, "{what}: messages");
    assert_eq!(a.mmsgs_per_sec, b.mmsgs_per_sec, "{what}: rate");
    assert_eq!(a.pcie, b.pcie, "{what}: PCIe counters");
    assert_eq!(a.pcie_read_rate, b.pcie_read_rate, "{what}: PCIe read rate");
    assert_eq!(a.p50_latency_ns, b.p50_latency_ns, "{what}: p50 latency");
    assert_eq!(a.p99_latency_ns, b.p99_latency_ns, "{what}: p99 latency");
    assert_eq!(a.sched_events, b.sched_events, "{what}: dispatched events");
    assert_eq!(a.sched_steps, b.sched_steps, "{what}: program phases");
    assert_eq!(a.cq_high_water, b.cq_high_water, "{what}: CQ occupancy");
}

fn dedicated_pool_vs_direct(policy: &EndpointPolicy, n: u32, cfg: MsgRateConfig, what: &str) {
    let (fabric, eps) = policy.build_fresh(n).unwrap();
    let direct = Runner::new(&fabric, &eps, cfg).run();
    let pooled = run_pooled(policy, n, n, MapStrategy::Dedicated, cfg).unwrap();
    assert_identical(&pooled.result, &direct, what);
    assert_eq!(pooled.migrations, 0, "{what}: dedicated mapping migrated");
}

#[test]
fn dedicated_pool_is_bit_identical_on_golden_fig2_cells() {
    let cfg = MsgRateConfig { msgs_per_thread: 2048, ..Default::default() };
    for n in [1u32, 2, 4, 8, 16] {
        for cat in [Category::MpiEverywhere, Category::MpiThreads] {
            let policy = EndpointPolicy::preset(cat);
            dedicated_pool_vs_direct(&policy, n, cfg, &format!("fig2 {cat} x{n}"));
        }
    }
}

#[test]
fn dedicated_pool_is_bit_identical_on_golden_fig9_fig11_cells() {
    for (fig, res) in [("fig9", SharedResource::Cq), ("fig11", SharedResource::Qp)] {
        for ways in [1u32, 2, 4, 8, 16] {
            for fs in FeatureSet::ALL_SETS.iter() {
                let policy = EndpointPolicy::sharing(res, ways);
                let cfg = MsgRateConfig {
                    msgs_per_thread: 2048,
                    features: fs.features(),
                    ..Default::default()
                };
                dedicated_pool_vs_direct(
                    &policy,
                    16,
                    cfg,
                    &format!("{fig} {ways}-way {:?}", fs.features()),
                );
            }
        }
    }
}

#[test]
fn scalable_pool_at_a_third_matches_dedicated_rate_with_fewer_resources() {
    // The tentpole acceptance: scalable endpoints pooled at
    // threads / 3 within 5 % of the dedicated per-thread rate at 16 and
    // 32 threads, at strictly lower resource usage. Both sides run the
    // §IV defaults (All features, 2 B writes) long enough to amortize
    // the startup/drain transients.
    let cfg = MsgRateConfig { msgs_per_thread: 16 * 1024, ..Default::default() };
    for n in [16u32, 32] {
        let dedicated =
            run_pooled(&EndpointPolicy::default(), n, n, MapStrategy::Dedicated, cfg)
                .unwrap();
        let third = run_pooled(
            &EndpointPolicy::scalable(),
            n,
            n / 3,
            MapStrategy::RoundRobin,
            cfg,
        )
        .unwrap();
        assert_eq!(third.result.messages, dedicated.result.messages, "x{n}");
        let rel = (third.result.mmsgs_per_sec / dedicated.result.mmsgs_per_sec - 1.0).abs();
        assert!(
            rel < 0.05,
            "x{n}: pool {} rate {:.2} vs dedicated {:.2} Mmsg/s (rel {:.3})",
            n / 3,
            third.result.mmsgs_per_sec,
            dedicated.result.mmsgs_per_sec,
            rel
        );
        let (tu, du) = (&third.usage, &dedicated.usage);
        assert!(tu.uuars_allocated < du.uuars_allocated, "x{n}: {tu:?} vs {du:?}");
        assert!(tu.uars_allocated < du.uars_allocated, "x{n}");
        assert!(tu.memory_bytes < du.memory_bytes, "x{n}");
        assert!(tu.qps < du.qps && tu.cqs < du.cqs, "x{n}");
    }
}

#[test]
fn strategies_trade_balance_for_state() {
    // Round-robin loads differ by at most one; hashed placement is
    // stateless but may skew; adaptive recovers round-robin-grade
    // balance from the hashed start via occupancy-driven migration.
    let cfg = MsgRateConfig { msgs_per_thread: 2048, ..Default::default() };
    let rr = run_pooled(&EndpointPolicy::scalable(), 16, 5, MapStrategy::RoundRobin, cfg)
        .unwrap();
    let ad = run_pooled(
        &EndpointPolicy::scalable(),
        16,
        5,
        MapStrategy::Adaptive { occupancy: 1 },
        cfg,
    )
    .unwrap();
    for (label, loads) in [("rr", &rr.loads), ("adaptive", &ad.loads)] {
        let (min, max) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(max - min <= 1, "{label} loads {loads:?}");
        assert_eq!(loads.iter().sum::<u32>(), 16, "{label}");
    }
    // Balanced mappings of one pool perform alike.
    let rel = (ad.result.mmsgs_per_sec / rr.result.mmsgs_per_sec - 1.0).abs();
    assert!(rel < 0.05, "balanced mappings diverged: {rel:.3}");
}
